//! Topology-aware collective schedules.
//!
//! The pod used to price (and simulate) every reduction as **one flat
//! ring over all `k` chips**. Real interconnects are hierarchical: chips
//! share a fast intra-node fabric, nodes hang off a slower inter-node
//! network, and the best reduction schedule depends on the payload —
//! big buckets want the bandwidth-optimal ring (flat or two-level),
//! tiny buckets want a latency-optimal tree (follow-up work to the
//! paper attributes much of the "54-minute BERT" speedup to exactly
//! this per-bucket schedule selection on hierarchical topologies).
//!
//! Two halves, one contract:
//!
//! * [`Topology`] — the *pricing* side. Describes the interconnect
//!   (`node_size` chips per node, distinct intra-/inter-node alpha-beta
//!   link models) and prices each [`ScheduleKind`] for each collective
//!   op; [`Topology::pick`] returns the cheapest schedule its
//!   [`SchedulePolicy`] allows. Every schedule obeys the ring's
//!   half-sum law (`reduce_scatter + all_gather == all_reduce`,
//!   bit-exact in f64) and costs exactly `0.0` at `k <= 1` — a single
//!   chip never pays for communication, in any schedule.
//! * [`ReduceSchedule`] — the *numeric* side, used by the exec engine's
//!   reduce paths. Every kind executes the **same single kernel**
//!   ([`super::reduce_mean`]: per-element f64 accumulation in global
//!   rank order) — deliberately, so the schedule choice is a pure
//!   performance decision that can never perturb training numerics
//!   (asserted bitwise by `tests/test_topology.rs`). A hierarchical
//!   leader chain folding node groups in rank order performs exactly
//!   this op sequence anyway, so there is nothing schedule-specific to
//!   stage on the host; the dispatch seam exists to carry the chosen
//!   kind (and node grouping) alongside the data path. ZeRO-3's staged
//!   execution plugs in exactly here: each parameter bucket's
//!   just-in-time all-gather is priced per bucket through
//!   [`Topology::pick`]`(CollOp::AllGather, ...)` before its
//!   forward/backward segment (`cluster::Pod`'s zero3 timeline), while
//!   the numeric gather stays the schedule-invariant
//!   [`ReduceSchedule::all_gather`] copy.
//!
//! ## Cost models
//!
//! With `rs(c, k, b)` = one ring half over link `c` (`(k-1)` phases,
//! `(k-1)/k * b` bytes per link — [`super::RingCost::reduce_scatter_time`]):
//!
//! * **Ring** (flat): `rs(link, k, b)` per half, where `link` is the
//!   slowest link the ring spans — `intra` while `k <= node_size`,
//!   `inter` otherwise (a flat ring over the whole pod crosses node
//!   boundaries, so the inter-node link is its bottleneck).
//! * **Hierarchical** (two-level): intra-node reduce-scatter over
//!   `k1 = min(node_size, k)` chips, then `k1` concurrent inter-node
//!   rings over `k2 = ceil(k/k1)` node leaders each moving only
//!   `b/k1` bytes, mirrored for the gather half. Inter-node traffic
//!   shrinks by the node size — the reason hierarchical wins whenever
//!   the inter-node link is the bottleneck.
//! * **Tree** (latency-optimal): binomial reduce + broadcast in
//!   `ceil(log2 k)` rounds of `alpha + b/beta` each per half. The
//!   latency term is logarithmic instead of linear in `k`, so the tree
//!   wins below a crossover payload; its bandwidth term is
//!   `log2(k) * b` instead of `~b`, so the ring wins above it.
//!
//! ## The mesh axes plug in here
//!
//! [`Topology::pick`] is the single pricing seam every parallel axis
//! goes through, at its own extent: the **dp** axis prices gradient
//! reduce-scatters/all-reduces and ZeRO-3 parameter gathers at
//! `k = dp`; the **tp** axis (`cluster::Mesh`) prices its per-layer
//! activation all-gathers and output reduce-scatters at `k = tp` —
//! which is `<= node_size` by validation, so they land on the
//! intra-node link and `span_link` keeps them nearly free; the **pp**
//! axis moves only microbatch boundary activations and is modeled as
//! the 1F1B bubble rather than a collective. Nothing mesh-specific
//! lives in this module: the axes differ only in the `k` and payload
//! they ask this seam to price.

use super::compress::{all_gather_wire, reduce_mean_ef, EfResiduals, Wire};
use super::RingCost;

/// A concrete reduction schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Flat ring over all `k` chips — the pre-topology default.
    #[default]
    Ring,
    /// Two-level: intra-node ring + inter-node ring over node leaders.
    Hierarchical,
    /// Binomial tree reduce + broadcast — latency-optimal for small
    /// payloads.
    Tree,
}

impl ScheduleKind {
    pub fn parse(s: &str) -> Option<ScheduleKind> {
        match s {
            "ring" => Some(ScheduleKind::Ring),
            "hierarchical" => Some(ScheduleKind::Hierarchical),
            "tree" => Some(ScheduleKind::Tree),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ScheduleKind::Ring => "ring",
            ScheduleKind::Hierarchical => "hierarchical",
            ScheduleKind::Tree => "tree",
        }
    }

    /// Every concrete kind, in the tie-breaking order [`Topology::pick`]
    /// uses (ring first: on a degenerate/flat topology where costs tie,
    /// the pre-topology default wins).
    pub const ALL: [ScheduleKind; 3] = [
        ScheduleKind::Ring,
        ScheduleKind::Hierarchical,
        ScheduleKind::Tree,
    ];
}

/// How a [`Topology`] chooses among schedules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Cheapest schedule per (op, payload) — may differ bucket to bucket.
    Auto,
    /// One fixed schedule for everything.
    Fixed(ScheduleKind),
}

impl Default for SchedulePolicy {
    fn default() -> Self {
        SchedulePolicy::Fixed(ScheduleKind::Ring)
    }
}

impl SchedulePolicy {
    /// Config spelling: `auto` or a [`ScheduleKind`] name.
    pub fn parse(s: &str) -> Option<SchedulePolicy> {
        if s == "auto" {
            return Some(SchedulePolicy::Auto);
        }
        ScheduleKind::parse(s).map(SchedulePolicy::Fixed)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SchedulePolicy::Auto => "auto",
            SchedulePolicy::Fixed(k) => k.as_str(),
        }
    }
}

/// The collective operation being priced (ZeRO-2 pays the two ring
/// halves at different points of the step, so they price separately).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollOp {
    AllReduce,
    ReduceScatter,
    AllGather,
}

/// Interconnect description + schedule policy: what the pod model asks
/// for the cheapest way to move each gradient bucket.
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    /// Chips per node. `1` means every link is inter-node (a flat
    /// topology); `>= k` means the whole slice shares the intra fabric.
    pub node_size: usize,
    /// Intra-node link (alpha s/phase, beta bytes/s).
    pub intra: RingCost,
    /// Inter-node link.
    pub inter: RingCost,
    /// Schedule selection policy.
    pub policy: SchedulePolicy,
    /// Steady-state pipelining: overlap ZeRO-2's trailing parameter
    /// all-gather with the *next* step's forward pass instead of
    /// exposing it whole (consumed by `cluster::Pod`'s timelines).
    pub cross_step: bool,
}

impl Topology {
    /// Flat topology over a single link — prices identically to the
    /// pre-topology `RingCost` model (the back-compat default).
    pub fn flat(link: RingCost) -> Topology {
        Topology {
            node_size: 1,
            intra: link,
            inter: link,
            policy: SchedulePolicy::Fixed(ScheduleKind::Ring),
            cross_step: false,
        }
    }

    /// Two-level topology with auto schedule selection.
    pub fn two_level(
        node_size: usize,
        intra: RingCost,
        inter: RingCost,
    ) -> Topology {
        Topology {
            node_size: node_size.max(1),
            intra,
            inter,
            policy: SchedulePolicy::Auto,
            cross_step: false,
        }
    }

    /// Intra/inter split of `k` chips: `k1` chips per node (clamped),
    /// `k2` nodes.
    fn split(&self, k: usize) -> (usize, usize) {
        let k1 = self.node_size.max(1).min(k.max(1));
        let k2 = (k.max(1) + k1 - 1) / k1;
        (k1, k2)
    }

    /// The slowest link a schedule spanning all `k` chips crosses.
    fn span_link(&self, k: usize) -> RingCost {
        if k <= self.node_size.max(1) {
            self.intra
        } else {
            self.inter
        }
    }

    /// Price `op` under a specific schedule kind. Exactly `0.0` for
    /// `k <= 1` in every kind (a single chip never communicates).
    pub fn op_time(
        &self,
        kind: ScheduleKind,
        op: CollOp,
        k: usize,
        bytes: usize,
    ) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        match op {
            CollOp::AllReduce => {
                self.op_time(kind, CollOp::ReduceScatter, k, bytes)
                    + self.op_time(kind, CollOp::AllGather, k, bytes)
            }
            CollOp::ReduceScatter | CollOp::AllGather => {
                // Every kind is wire-symmetric: the scatter and gather
                // halves cost the same, so the half-sum law
                // (`rs + ag == allreduce`) holds bit-exactly.
                self.half_time(kind, k, bytes)
            }
        }
    }

    /// One symmetric half (reduce-scatter or all-gather) of `kind`.
    fn half_time(&self, kind: ScheduleKind, k: usize, bytes: usize) -> f64 {
        match kind {
            ScheduleKind::Ring => {
                self.span_link(k).reduce_scatter_time(k, bytes)
            }
            ScheduleKind::Hierarchical => {
                let (k1, k2) = self.split(k);
                // Stage 1: ring half inside each node (concurrent across
                // nodes). Stage 2: k1 concurrent inter-node ring halves
                // over the node leaders, each carrying only its 1/k1
                // shard of the payload.
                let inter_bytes = (bytes + k1 - 1) / k1;
                self.intra.reduce_scatter_time(k1, bytes)
                    + self.inter.reduce_scatter_time(k2, inter_bytes)
            }
            ScheduleKind::Tree => {
                // Binomial reduce (or broadcast): ceil(log2 k) rounds,
                // each moving the whole payload one hop.
                let rounds = (usize::BITS - (k - 1).leading_zeros()) as f64;
                let link = self.span_link(k);
                rounds * (link.alpha + bytes as f64 / link.beta)
            }
        }
    }

    /// The schedule kinds this topology's policy may choose from, in
    /// tie-breaking order. Borrows (no allocation): [`Topology::pick`]
    /// runs per bucket in every timeline pricing call.
    pub fn candidates(&self) -> &[ScheduleKind] {
        match &self.policy {
            SchedulePolicy::Fixed(k) => std::slice::from_ref(k),
            SchedulePolicy::Auto => &ScheduleKind::ALL,
        }
    }

    /// Cheapest allowed schedule for `op` at this payload: the core of
    /// per-bucket algorithm selection. Ties break toward the earlier
    /// candidate (ring first), so a flat topology under `auto` still
    /// reports the pre-topology default where costs coincide.
    // candidates() returns a non-empty slice by construction (Fixed is
    // one kind, Auto is ScheduleKind::ALL), so the final expect is an
    // invariant, not an error path.
    #[allow(clippy::expect_used)]
    pub fn pick(&self, op: CollOp, k: usize, bytes: usize) -> (ScheduleKind, f64) {
        let mut best = None;
        for &kind in self.candidates() {
            let t = self.op_time(kind, op, k, bytes);
            match best {
                Some((_, bt)) if t >= bt => {}
                _ => best = Some((kind, t)),
            }
        }
        best.expect("no schedule candidates")
    }

    /// Cheapest all-reduce time (policy-filtered).
    pub fn time(&self, k: usize, bytes: usize) -> f64 {
        self.pick(CollOp::AllReduce, k, bytes).1
    }

    /// Cheapest reduce-scatter time (policy-filtered).
    pub fn reduce_scatter_time(&self, k: usize, bytes: usize) -> f64 {
        self.pick(CollOp::ReduceScatter, k, bytes).1
    }

    /// Cheapest all-gather time (policy-filtered).
    pub fn all_gather_time(&self, k: usize, bytes: usize) -> f64 {
        self.pick(CollOp::AllGather, k, bytes).1
    }
}

/// Numeric execution side of a schedule. All kinds run the single
/// [`super::reduce_mean`] kernel (see module docs: the rank-order reduction
/// *is* the bit-level contract, and no host-side staging differs from
/// it); the struct carries which schedule — and which node grouping —
/// the data path is logically executing, matching what the cost model
/// priced.
#[derive(Clone, Copy, Debug)]
pub struct ReduceSchedule {
    pub kind: ScheduleKind,
    /// Node grouping of the worker ranks (the hierarchical schedule's
    /// wire pattern); informational on the host data path.
    pub node_size: usize,
    /// Format the elements cross the wire in ([`Wire::F32`] keeps the
    /// plain kernels bitwise; half dtypes round every contribution and
    /// result through the storage dtype; the compressed formats run the
    /// error-feedback kernels in [`super::compress`]). Unlike `kind`,
    /// this is a *numeric* choice: a narrow wire changes bits,
    /// deterministically and rank-order invariantly.
    pub wire: Wire,
    /// Error feedback for the compressed wires: residual buffers
    /// compensate the quantization error across steps. On by default;
    /// turning it off (convergence regression tests do) quantizes
    /// without residual state. Ignored by the uncompressed wires.
    pub error_feedback: bool,
}

impl Default for ReduceSchedule {
    fn default() -> Self {
        ReduceSchedule {
            kind: ScheduleKind::Ring,
            node_size: 1,
            wire: Wire::F32,
            error_feedback: true,
        }
    }
}

impl ReduceSchedule {
    pub fn new(kind: ScheduleKind, node_size: usize) -> ReduceSchedule {
        ReduceSchedule {
            kind,
            node_size: node_size.max(1),
            wire: Wire::F32,
            error_feedback: true,
        }
    }

    /// Same schedule, elements crossing the wire in `wire` format.
    pub fn with_wire(mut self, wire: Wire) -> ReduceSchedule {
        self.wire = wire;
        self
    }

    /// Same schedule with error feedback toggled (compressed wires only).
    pub fn with_error_feedback(mut self, on: bool) -> ReduceSchedule {
        self.error_feedback = on;
        self
    }

    /// Static telemetry counter name `wire_bytes.<op>.<wire format>` —
    /// the host-trace recorder takes `&'static str` names so the hot
    /// path never allocates.
    fn wire_counter(&self, op: CollOp) -> &'static str {
        match (op, self.wire) {
            (CollOp::AllReduce, Wire::F32) => "wire_bytes.all_reduce.f32",
            (CollOp::AllReduce, Wire::Bf16) => "wire_bytes.all_reduce.bf16",
            (CollOp::AllReduce, Wire::F16) => "wire_bytes.all_reduce.f16",
            (CollOp::AllReduce, Wire::F8) => "wire_bytes.all_reduce.f8",
            (CollOp::AllReduce, Wire::OneBit) => "wire_bytes.all_reduce.1bit",
            (CollOp::ReduceScatter, Wire::F32) => {
                "wire_bytes.reduce_scatter.f32"
            }
            (CollOp::ReduceScatter, Wire::Bf16) => {
                "wire_bytes.reduce_scatter.bf16"
            }
            (CollOp::ReduceScatter, Wire::F16) => {
                "wire_bytes.reduce_scatter.f16"
            }
            (CollOp::ReduceScatter, Wire::F8) => {
                "wire_bytes.reduce_scatter.f8"
            }
            (CollOp::ReduceScatter, Wire::OneBit) => {
                "wire_bytes.reduce_scatter.1bit"
            }
            (CollOp::AllGather, Wire::F32) => "wire_bytes.all_gather.f32",
            (CollOp::AllGather, Wire::Bf16) => "wire_bytes.all_gather.bf16",
            (CollOp::AllGather, Wire::F16) => "wire_bytes.all_gather.f16",
            (CollOp::AllGather, Wire::F8) => "wire_bytes.all_gather.f8",
            (CollOp::AllGather, Wire::OneBit) => "wire_bytes.all_gather.1bit",
        }
    }

    /// Average per-worker buffers into `out` — the single rank-order
    /// kernel for every kind, so this is bitwise-identical to
    /// [`super::reduce_mean`] by construction at f32 wire (a ring
    /// streams the flat rank order; a pipelined chain tree and a
    /// hierarchical leader chain folding node groups in rank order
    /// perform the same op sequence). A narrower wire quantizes each
    /// contribution and the mean through the wire format — still one
    /// deterministic rank-order kernel for every kind. This entry point
    /// reduces a range starting at global element 0 with no residual
    /// state; the exec engine's bucketed paths use
    /// [`ReduceSchedule::reduce_mean_ef`].
    pub fn reduce_mean(&self, workers: &[&[f32]], out: &mut [f32]) {
        self.reduce_mean_ef(0, workers, None, out);
    }

    /// [`ReduceSchedule::reduce_mean`] with the compressed-wire context:
    /// `offset` anchors the 1-bit chunk grid to the bucket's position in
    /// the flat gradient (so dense and ZeRO-sharded reduces chunk
    /// identically), and `residuals` carries the error-feedback state.
    /// Residuals are ignored when error feedback is off or the wire is
    /// uncompressed.
    pub fn reduce_mean_ef(
        &self,
        offset: usize,
        workers: &[&[f32]],
        residuals: Option<EfResiduals<'_, '_>>,
        out: &mut [f32],
    ) {
        if crate::trace::host::enabled() {
            crate::trace::host::counter(
                self.wire_counter(CollOp::AllReduce),
                self.wire.payload_bytes(out.len()) as f64,
            );
        }
        let residuals = if self.error_feedback { residuals } else { None };
        reduce_mean_ef(self.wire, offset, workers, residuals, out);
    }

    /// Reduce-scatter (mean) of the flat range `[start, end)` — the
    /// ZeRO-2 half. Same schedule-invariance contract. Range starts are
    /// worker-buffer-local; `offset` (see
    /// [`ReduceSchedule::reduce_mean_ef`]) is added on top to anchor the
    /// 1-bit chunk grid globally.
    pub fn reduce_scatter_mean(
        &self,
        workers: &[&[f32]],
        start: usize,
        end: usize,
        out: &mut [f32],
    ) {
        self.reduce_scatter_mean_ef(0, workers, start, end, None, out);
    }

    /// [`ReduceSchedule::reduce_scatter_mean`] with compressed-wire
    /// context (global offset + error-feedback residuals covering the
    /// scattered range).
    pub fn reduce_scatter_mean_ef(
        &self,
        offset: usize,
        workers: &[&[f32]],
        start: usize,
        end: usize,
        residuals: Option<EfResiduals<'_, '_>>,
        out: &mut [f32],
    ) {
        assert!(start <= end, "inverted range");
        assert_eq!(out.len(), end - start, "output length != range length");
        let slices: Vec<&[f32]> = workers
            .iter()
            .map(|w| {
                assert!(end <= w.len(), "range exceeds worker buffer");
                &w[start..end]
            })
            .collect();
        if crate::trace::host::enabled() {
            crate::trace::host::counter(
                self.wire_counter(CollOp::ReduceScatter),
                self.wire.payload_bytes(end - start) as f64,
            );
        }
        let residuals = if self.error_feedback { residuals } else { None };
        // Straight to the kernel — routing through `reduce_mean` would
        // double-count the payload as an all-reduce.
        reduce_mean_ef(self.wire, offset + start, &slices, residuals, out);
    }

    /// All-gather: stitch owner chunks back into the flat vector —
    /// identical for every kind (the schedule only changes the wire
    /// pattern, which the cost model prices). At f32 wire a pure copy;
    /// a half wire rounds each element through the storage dtype (a
    /// no-op for chunks already holding storage-dtype values —
    /// quantization is idempotent). The compressed wires gather values
    /// that already came out of the stage-B quantizer, so they copy raw
    /// while the counter prices the compressed payload.
    pub fn all_gather(&self, shards: &[(usize, &[f32])], out: &mut [f32]) {
        if crate::trace::host::enabled() {
            let elems: usize = shards.iter().map(|(_, s)| s.len()).sum();
            crate::trace::host::counter(
                self.wire_counter(CollOp::AllGather),
                self.wire.payload_bytes(elems) as f64,
            );
        }
        all_gather_wire(self.wire, shards, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{reduce_mean, REDUCE_CHUNK};

    fn tpu_link() -> RingCost {
        RingCost { alpha: 4.4e-5, beta: 70e9 }
    }

    fn pod_topo() -> Topology {
        // 8-chip nodes on a fast local fabric, pod-scale inter link.
        Topology::two_level(
            8,
            RingCost { alpha: 1e-6, beta: 600e9 },
            tpu_link(),
        )
    }

    #[test]
    fn schedule_kind_parse_roundtrip() {
        for k in ScheduleKind::ALL {
            assert_eq!(ScheduleKind::parse(k.as_str()), Some(k));
            assert_eq!(
                SchedulePolicy::parse(k.as_str()),
                Some(SchedulePolicy::Fixed(k))
            );
        }
        assert_eq!(SchedulePolicy::parse("auto"), Some(SchedulePolicy::Auto));
        assert_eq!(ScheduleKind::parse("auto"), None);
        assert_eq!(ScheduleKind::parse("mesh"), None);
        assert_eq!(SchedulePolicy::Auto.as_str(), "auto");
        assert_eq!(
            SchedulePolicy::Fixed(ScheduleKind::Tree).as_str(),
            "tree"
        );
    }

    /// Regression (ISSUE 3): communication costs exactly 0 for a single
    /// chip in every schedule and every op — including degenerate
    /// hierarchies where node_size exceeds the chip count.
    #[test]
    fn single_chip_costs_exactly_zero_in_all_schedules() {
        for topo in [Topology::flat(tpu_link()), pod_topo()] {
            for kind in ScheduleKind::ALL {
                for op in
                    [CollOp::AllReduce, CollOp::ReduceScatter, CollOp::AllGather]
                {
                    assert_eq!(topo.op_time(kind, op, 1, 1 << 30), 0.0);
                    assert_eq!(topo.op_time(kind, op, 0, 1 << 30), 0.0);
                }
            }
            assert_eq!(topo.time(1, 1 << 30), 0.0);
        }
    }

    /// `flat(ring)` prices the ring schedule exactly like the bare
    /// `RingCost` the pod used before the topology refactor.
    #[test]
    fn flat_ring_matches_pre_topology_cost_bitwise() {
        let link = tpu_link();
        let topo = Topology::flat(link);
        for &k in &[2usize, 16, 64, 1024] {
            for &bytes in &[4096usize, 1 << 20, 1_336_000_000] {
                let rs = topo.op_time(
                    ScheduleKind::Ring,
                    CollOp::ReduceScatter,
                    k,
                    bytes,
                );
                let ag = topo.op_time(
                    ScheduleKind::Ring,
                    CollOp::AllGather,
                    k,
                    bytes,
                );
                let ar =
                    topo.op_time(ScheduleKind::Ring, CollOp::AllReduce, k, bytes);
                assert_eq!(rs, link.reduce_scatter_time(k, bytes));
                assert_eq!(ag, link.all_gather_time(k, bytes));
                // rs + rs == 2.0 * rs exactly in IEEE f64
                assert_eq!(ar, link.time(k, bytes));
                // the policy-filtered entry points agree (default = ring)
                assert_eq!(topo.time(k, bytes), ar);
                assert_eq!(topo.reduce_scatter_time(k, bytes), rs);
                assert_eq!(topo.all_gather_time(k, bytes), ag);
            }
        }
    }

    /// The half-sum law holds bit-exactly for every kind.
    #[test]
    fn halves_sum_to_all_reduce_every_kind() {
        let topo = pod_topo();
        for kind in ScheduleKind::ALL {
            for &k in &[2usize, 7, 8, 64, 1000, 1024] {
                for &bytes in &[1usize, 4096, 1 << 20, 1 << 30] {
                    let rs =
                        topo.op_time(kind, CollOp::ReduceScatter, k, bytes);
                    let ag = topo.op_time(kind, CollOp::AllGather, k, bytes);
                    let ar = topo.op_time(kind, CollOp::AllReduce, k, bytes);
                    assert_eq!(rs + ag, ar, "{kind:?} k={k} bytes={bytes}");
                }
            }
        }
    }

    /// Hierarchical beats the flat ring whenever the inter-node link is
    /// the bottleneck (slower than intra and spanning more chips).
    #[test]
    fn hierarchical_beats_flat_ring_when_inter_bound() {
        let topo = pod_topo();
        for &k in &[16usize, 64, 256, 1024] {
            for &bytes in &[1usize << 12, 1 << 20, 1 << 27, 1_336_000_000] {
                let ring =
                    topo.op_time(ScheduleKind::Ring, CollOp::AllReduce, k, bytes);
                let hier = topo.op_time(
                    ScheduleKind::Hierarchical,
                    CollOp::AllReduce,
                    k,
                    bytes,
                );
                assert!(
                    hier <= ring,
                    "k={k} bytes={bytes}: hier {hier} vs ring {ring}"
                );
            }
        }
    }

    /// The tree wins below a crossover payload (latency-bound) and
    /// loses above it (bandwidth-bound) on a pod-scale flat link.
    #[test]
    fn tree_wins_small_ring_wins_big() {
        let topo = Topology::flat(tpu_link());
        let k = 1024;
        let small = 4 * 1024; // 4 KiB bucket: 2*1023 ring phases dominate
        let big = 1 << 30; // 1 GiB bucket: log2(k) extra payload copies
        let ring_s = topo.op_time(ScheduleKind::Ring, CollOp::AllReduce, k, small);
        let tree_s = topo.op_time(ScheduleKind::Tree, CollOp::AllReduce, k, small);
        let ring_b = topo.op_time(ScheduleKind::Ring, CollOp::AllReduce, k, big);
        let tree_b = topo.op_time(ScheduleKind::Tree, CollOp::AllReduce, k, big);
        assert!(tree_s < ring_s, "{tree_s} vs {ring_s}");
        assert!(ring_b < tree_b, "{ring_b} vs {tree_b}");
    }

    /// `auto` is exactly the min over the fixed choices — never slower
    /// than the worst one (or indeed any of them).
    #[test]
    fn auto_is_min_over_fixed_choices() {
        let mut topo = pod_topo();
        topo.policy = SchedulePolicy::Auto;
        for &k in &[2usize, 8, 64, 1024] {
            for &bytes in &[64usize, 4096, 1 << 20, 1 << 28] {
                for op in
                    [CollOp::AllReduce, CollOp::ReduceScatter, CollOp::AllGather]
                {
                    let times: Vec<f64> = ScheduleKind::ALL
                        .iter()
                        .map(|&kind| topo.op_time(kind, op, k, bytes))
                        .collect();
                    let (kind, t) = topo.pick(op, k, bytes);
                    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
                    let max = times.iter().cloned().fold(0.0, f64::max);
                    assert_eq!(t, min, "k={k} bytes={bytes} {op:?}");
                    assert!(t <= max);
                    assert_eq!(topo.op_time(kind, op, k, bytes), t);
                }
            }
        }
    }

    /// All numeric paths produce the exact bits of `reduce_mean`,
    /// including across chunk boundaries and non-dividing node sizes.
    #[test]
    fn numeric_paths_bitwise_equal_reduce_mean() {
        let mut rng = crate::util::Rng::new(31);
        for &(k, n) in &[
            (1usize, 7usize),
            (5, 129),
            (8, REDUCE_CHUNK + 13),
            (3, 2 * REDUCE_CHUNK),
        ] {
            let bufs: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.normal_f32(2.0)).collect())
                .collect();
            let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
            let mut want = vec![0.0f32; n];
            reduce_mean(&refs, &mut want);
            for kind in ScheduleKind::ALL {
                for node in [1usize, 2, 3, 8, 100] {
                    let sched = ReduceSchedule::new(kind, node);
                    let mut got = vec![0.0f32; n];
                    sched.reduce_mean(&refs, &mut got);
                    for i in 0..n {
                        assert_eq!(
                            got[i].to_bits(),
                            want[i].to_bits(),
                            "{kind:?} node={node} k={k} i={i}"
                        );
                    }
                }
            }
        }
    }
}
