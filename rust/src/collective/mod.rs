//! Synchronous gradient collectives.
//!
//! Three faces, one contract:
//!
//! * [`reduce_mean`] — the numeric hot path: average the per-worker
//!   gradient shards into one buffer (what the TPU interconnect computes).
//! * [`reduce_scatter_mean`] / [`all_gather`] — the ZeRO-2 halves of the
//!   same reduction: each rank receives only the averaged chunk it owns,
//!   and updated chunks are later stitched back into the replicated
//!   vector. Per element the arithmetic is identical to [`reduce_mean`],
//!   so the split pipeline is bitwise-equal to the monolithic one
//!   (asserted by `tests/test_exec.rs`).
//! * [`RingAllReduce`] — a faithful chunked ring simulation
//!   (reduce-scatter + all-gather over 2(k-1) phases) used by tests to
//!   prove the hot path computes exactly what a ring would, and by the
//!   pod model to price each phase with the alpha-beta cost model that
//!   Figure 8's scaling-efficiency curve comes from.
//!
//! The [`topology`] submodule generalizes the flat ring into pluggable
//! reduction schedules ([`ScheduleKind`]: ring / hierarchical two-level /
//! latency-optimal tree) priced over a [`Topology`] with distinct
//! intra-/inter-node links, plus the [`ReduceSchedule`] numeric dispatch
//! the exec engine uses — every schedule's numeric path is
//! bitwise-identical to [`reduce_mean`], so schedule choice is a pure
//! performance decision.
//!
//! The [`precision`] submodule adds the orthogonal axis: what *dtype*
//! each element crosses the wire in. [`Precision`] (f32 / bf16 / f16)
//! supplies deterministic software quantization,
//! [`reduce_mean_quant`] / [`all_gather_quant`] are the
//! quantize-on-wire collective variants (f32 mode is bitwise-identical
//! to the plain kernels — it *is* the plain kernel), and
//! [`ReduceSchedule::wire`] threads the choice through the exec
//! engine's reduce paths while the topology prices the halved payload.
//!
//! The [`compress`] submodule extends the wire axis past the storage
//! dtypes: [`Wire`] adds E4M3 fp8 and 1-bit (sign + per-chunk scale)
//! gradient wire formats, shipped as error-feedback collectives
//! ([`reduce_mean_ef`]) whose persistent residuals make the compressed
//! reduce unbiased over steps. F32 wire mode remains bitwise the plain
//! kernel, and 1-bit chunk grids are anchored to global element offsets
//! so dense and ZeRO-sharded reduces stay bitwise equal.
//!
//! ## Ring cost model
//!
//! A ring all-reduce over `k` ranks is a reduce-scatter followed by an
//! all-gather, each of `k-1` phases moving `bytes/k` per link per phase —
//! `(k-1)/k` of the buffer per half. [`RingCost::time`] prices the full
//! pair; [`RingCost::reduce_scatter_time`] and
//! [`RingCost::all_gather_time`] price each half alone (what ZeRO-2 pays
//! at distinct points of the step: gradients are reduce-scattered under
//! the backward pass, updated parameters are all-gathered after the
//! owner's optimizer step). The two halves sum exactly to the all-reduce
//! time.

// The collective stack is part of the determinism-critical core: no
// silent panics (errors must carry enough context to debug a pod-scale
// run), enforced module-wide and inherited by the submodules below.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod compress;
pub mod precision;
pub mod topology;

pub use compress::{
    all_gather_wire, ef_transmit, quantize_slice, reduce_mean_ef,
    EfResiduals, Wire, ONEBIT_CHUNK,
};
pub use precision::{
    all_gather_quant, reduce_mean_quant, reduce_scatter_mean_quant,
    Precision, PrecisionPlan,
};
pub use topology::{
    CollOp, ReduceSchedule, ScheduleKind, SchedulePolicy, Topology,
};

/// Elements per chunk of the reduction working set. 4096 f64 = 32 KiB —
/// fits L1d alongside one worker slice, large enough to amortize the
/// per-chunk loop overhead.
pub(crate) const REDUCE_CHUNK: usize = 4096;

/// Average `workers` gradient buffers into `out` (all same length).
/// Accumulates in f64 — the same reduction order for any worker count, so
/// batch-size sweeps are bitwise comparable.
///
/// The loop nest is chunked with workers *outside* elements: each inner
/// pass streams one contiguous per-worker slice into an f64 scratch
/// buffer, which vectorizes, instead of gathering one element from every
/// worker per iteration (the old layout defeated vectorization and
/// touched `k` cache lines per element). Per element the arithmetic is
/// still `(0 + w0 + w1 + ... + wk-1) * (1/k)` in worker order, so results
/// are bit-identical to the pre-chunked implementation.
pub fn reduce_mean(workers: &[&[f32]], out: &mut [f32]) {
    reduce_mean_mapped(workers, out, |x| x);
}

/// The single chunked rank-order kernel behind [`reduce_mean`]
/// (identity map) and the quantize-on-wire variant
/// ([`precision::reduce_mean_quant`]): `map` is applied to every loaded
/// contribution and to the stored mean. Sharing the kernel keeps the
/// two paths provably in lockstep — same chunking, same f64
/// worker-order accumulation — so the per-element map is the *only*
/// numeric difference between them.
pub(crate) fn reduce_mean_mapped(
    workers: &[&[f32]],
    out: &mut [f32],
    map: impl Fn(f32) -> f32,
) {
    let k = workers.len();
    assert!(k > 0, "no workers");
    for w in workers {
        assert_eq!(w.len(), out.len(), "shard length mismatch");
    }
    let inv = 1.0f64 / k as f64;
    let mut scratch = [0.0f64; REDUCE_CHUNK];
    let mut base = 0;
    while base < out.len() {
        let len = REDUCE_CHUNK.min(out.len() - base);
        let acc = &mut scratch[..len];
        for a in acc.iter_mut() {
            *a = 0.0;
        }
        for w in workers {
            let ws = &w[base..base + len];
            for (a, &x) in acc.iter_mut().zip(ws) {
                *a += map(x) as f64;
            }
        }
        let oc = &mut out[base..base + len];
        for (o, &a) in oc.iter_mut().zip(acc.iter()) {
            *o = map((a * inv) as f32);
        }
        base += len;
    }
}

/// Reduce-scatter (mean): average the flat range `[start, end)` of every
/// worker buffer into the range-local `out` (length `end - start`) — the
/// chunk its owner keeps under ZeRO-2.
///
/// Delegates to [`reduce_mean`] over the worker sub-slices, so element
/// `start + i` of the result is bitwise-identical to element `start + i`
/// of a monolithic `reduce_mean` over the full buffers (the reduction is
/// strictly per-element).
pub fn reduce_scatter_mean(
    workers: &[&[f32]],
    start: usize,
    end: usize,
    out: &mut [f32],
) {
    assert!(start <= end, "inverted range");
    assert_eq!(out.len(), end - start, "output length != range length");
    let slices: Vec<&[f32]> = workers
        .iter()
        .map(|w| {
            assert!(end <= w.len(), "range exceeds worker buffer");
            &w[start..end]
        })
        .collect();
    reduce_mean(&slices, out);
}

/// All-gather: stitch per-owner chunks back into the full flat vector.
/// `shards` is a list of `(start_offset, chunk)` pairs; each chunk is
/// copied into `out[start..start + chunk.len()]`. Chunks must not exceed
/// `out`; overlapping chunks are allowed but last-writer-wins (the exec
/// engine always passes a disjoint bucket partition). This is also the
/// numeric half of ZeRO-3's just-in-time parameter broadcast: gathering
/// one bucket's owner shard into the transient view is a single-pair
/// call (`exec::Zero3State::gather_bucket`), priced per bucket by the
/// topology's `CollOp::AllGather`.
pub fn all_gather(shards: &[(usize, &[f32])], out: &mut [f32]) {
    for &(start, chunk) in shards {
        assert!(
            start + chunk.len() <= out.len(),
            "shard [{start}, {}) exceeds output length {}",
            start + chunk.len(),
            out.len()
        );
        out[start..start + chunk.len()].copy_from_slice(chunk);
    }
}

/// Sum-accumulate `src` into `acc` (microbatch gradient accumulation).
pub fn accumulate(acc: &mut [f32], src: &[f32]) {
    assert_eq!(acc.len(), src.len());
    for i in 0..acc.len() {
        // detlint: allow(f32-accum) microbatch accumulation is defined in
        // fixed microbatch order; f32 += here IS the contract (matches the
        // on-device accumulator), not an unordered reduction.
        acc[i] += src[i];
    }
}

/// Scale a buffer in place (finishing an accumulation into a mean).
pub fn scale(buf: &mut [f32], s: f32) {
    for x in buf.iter_mut() {
        *x *= s;
    }
}

/// Alpha-beta cost model of one ring all-reduce over `k` workers for a
/// payload of `bytes` per worker.
///
/// Ring all-reduce moves `2*(k-1)/k * bytes` per link in `2*(k-1)` phases:
/// `time = 2*(k-1)*alpha + 2*(k-1)/k * bytes / beta`.
#[derive(Clone, Copy, Debug)]
pub struct RingCost {
    /// Per-phase latency (s).
    pub alpha: f64,
    /// Per-link bandwidth (bytes/s).
    pub beta: f64,
}

impl RingCost {
    /// Full all-reduce: exactly two equal ring halves, so the invariant
    /// `reduce_scatter_time + all_gather_time == time` holds by
    /// construction (doubling is exact in f64).
    ///
    /// A single chip (`k <= 1`) communicates with nobody: the cost is
    /// exactly `0.0`, guarded here explicitly rather than relying on the
    /// `k - 1` phase count degenerating (the [`topology`] schedules all
    /// share this contract — see
    /// `single_chip_costs_exactly_zero_in_all_schedules`).
    pub fn time(&self, k: usize, bytes: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        2.0 * self.reduce_scatter_time(k, bytes)
    }

    /// One half of the ring: `k-1` phases moving `(k-1)/k * bytes` total
    /// per link — `time = (k-1)*alpha + (k-1)/k * bytes / beta`. This is
    /// the reduce-scatter a ZeRO-2 step pays per gradient bucket (and it
    /// overlaps with the backward pass exactly like the all-reduce).
    pub fn reduce_scatter_time(&self, k: usize, bytes: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let phases = k as f64 - 1.0;
        phases * self.alpha
            + (phases / k as f64) * (bytes as f64) / self.beta
    }

    /// The other half of the ring — identical wire profile to
    /// [`Self::reduce_scatter_time`]. Under ZeRO-2 this is the parameter
    /// all-gather after the owners' optimizer step, which cannot hide
    /// under backward compute (the step is already over).
    pub fn all_gather_time(&self, k: usize, bytes: usize) -> f64 {
        self.reduce_scatter_time(k, bytes)
    }
}

/// Step-by-step ring all-reduce simulation: produces the averaged result
/// via the actual reduce-scatter / all-gather chunk schedule.
pub struct RingAllReduce {
    pub k: usize,
}

impl RingAllReduce {
    pub fn new(k: usize) -> RingAllReduce {
        assert!(k > 0);
        RingAllReduce { k }
    }

    /// Run the ring schedule over per-worker buffers in place; afterwards
    /// every worker holds the mean. Returns the number of communication
    /// phases executed (for cost-model cross-checks).
    pub fn run(&self, bufs: &mut [Vec<f32>]) -> usize {
        let k = self.k;
        assert_eq!(bufs.len(), k);
        if k == 1 {
            return 0;
        }
        let n = bufs[0].len();
        // Chunk boundaries: chunk c = [start(c), start(c+1)).
        let start = |c: usize| (c * n) / k;
        let mut phases = 0;

        // Reduce-scatter: phase p, worker w sends chunk (w - p) mod k to
        // worker (w+1) mod k, which accumulates.
        for p in 0..k - 1 {
            for w in 0..k {
                let src = w;
                let dst = (w + 1) % k;
                let c = (w + k - p) % k;
                let (a, b) = (start(c), start(c + 1));
                // split_at_mut dance to borrow two workers at once
                let (lo, hi) = if src < dst {
                    let (l, h) = bufs.split_at_mut(dst);
                    (&l[src], &mut h[0])
                } else {
                    let (l, h) = bufs.split_at_mut(src);
                    (&h[0], &mut l[dst])
                };
                // note: when src<dst, lo=src buffer (immutable), hi=dst
                for i in a..b {
                    // detlint: allow(f32-accum) this models the physical
                    // ring's wire arithmetic (fixed phase order); the hot
                    // path uses the f64-scratch reduce_mean instead.
                    hi[i] += lo[i];
                }
                phases += 1;
            }
        }
        // Chunk c is sent at phase p by worker (c+p) mod k; after the last
        // phase (p = k-2) its full sum rests at worker (c-1) mod k.
        // Normalize there, then all-gather ring-style.
        let mut tmp = Vec::new();
        for c in 0..k {
            let owner = (c + k - 1) % k;
            let (a, b) = (start(c), start(c + 1));
            for i in a..b {
                bufs[owner][i] /= k as f32;
            }
            tmp.clear();
            tmp.extend_from_slice(&bufs[owner][a..b]);
            for p in 1..k {
                let dst = (owner + p) % k;
                bufs[dst][a..b].copy_from_slice(&tmp);
                phases += 1;
            }
        }
        phases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_of_workers() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 2.0, 1.0];
        let mut out = vec![0.0f32; 3];
        reduce_mean(&[&a, &b], &mut out);
        assert_eq!(out, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn accumulate_and_scale() {
        let mut acc = vec![1.0f32, 1.0];
        accumulate(&mut acc, &[2.0, 3.0]);
        scale(&mut acc, 0.5);
        assert_eq!(acc, vec![1.5, 2.0]);
    }

    /// The chunked implementation must match the definitional
    /// element-at-a-time reduction bit-for-bit, including across chunk
    /// boundaries (n > REDUCE_CHUNK) and ragged tails.
    #[test]
    fn chunked_matches_reference_bitwise() {
        let mut rng = crate::util::Rng::new(9);
        for &(k, n) in &[(1usize, 5usize), (3, REDUCE_CHUNK - 1), (4, REDUCE_CHUNK + 37), (2, 3 * REDUCE_CHUNK)] {
            let bufs: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.normal_f32(2.0)).collect())
                .collect();
            let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
            let mut got = vec![0.0f32; n];
            reduce_mean(&refs, &mut got);
            let inv = 1.0f64 / k as f64;
            for i in 0..n {
                let mut acc = 0.0f64;
                for w in &refs {
                    acc += w[i] as f64;
                }
                let want = (acc * inv) as f32;
                assert!(
                    got[i].to_bits() == want.to_bits(),
                    "i={i}: {} vs {}",
                    got[i],
                    want
                );
            }
        }
    }

    /// Reduce-scatter of a range must reproduce that range of the
    /// monolithic reduce bitwise, and all-gather must stitch a disjoint
    /// partition back losslessly.
    #[test]
    fn scatter_then_gather_matches_reduce_mean_bitwise() {
        let mut rng = crate::util::Rng::new(17);
        let n = 257; // deliberately odd: ragged against any chunking
        let k = 3;
        let bufs: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..n).map(|_| rng.normal_f32(1.5)).collect())
            .collect();
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let mut whole = vec![0.0f32; n];
        reduce_mean(&refs, &mut whole);
        // ragged 3-way partition of [0, n)
        let cuts = [0usize, 100, 101, n];
        let mut shards: Vec<Vec<f32>> = Vec::new();
        for w in cuts.windows(2) {
            let (a, b) = (w[0], w[1]);
            let mut s = vec![0.0f32; b - a];
            reduce_scatter_mean(&refs, a, b, &mut s);
            shards.push(s);
        }
        for (i, w) in cuts.windows(2).enumerate() {
            for (j, &v) in shards[i].iter().enumerate() {
                assert_eq!(v.to_bits(), whole[w[0] + j].to_bits());
            }
        }
        let parts: Vec<(usize, &[f32])> = cuts
            .windows(2)
            .zip(&shards)
            .map(|(w, s)| (w[0], s.as_slice()))
            .collect();
        let mut gathered = vec![0.0f32; n];
        all_gather(&parts, &mut gathered);
        for i in 0..n {
            assert_eq!(gathered[i].to_bits(), whole[i].to_bits(), "i={i}");
        }
    }

    /// Regression (ISSUE 3): a single chip pays exactly zero in every
    /// entry point of the ring cost model, for any payload.
    #[test]
    fn single_chip_ring_cost_is_exactly_zero() {
        let c = RingCost { alpha: 4.4e-5, beta: 70e9 };
        for &bytes in &[0usize, 1, 1 << 20, 1_336_000_000] {
            for k in [0usize, 1] {
                assert_eq!(c.time(k, bytes), 0.0);
                assert_eq!(c.reduce_scatter_time(k, bytes), 0.0);
                assert_eq!(c.all_gather_time(k, bytes), 0.0);
            }
        }
    }

    #[test]
    fn cost_model_shape() {
        let c = RingCost { alpha: 1e-6, beta: 70e9 };
        assert_eq!(c.time(1, 1 << 30), 0.0);
        // Bandwidth term saturates as k grows: time(k) -> 2*bytes/beta.
        let t64 = c.time(64, 1 << 30);
        let t1024 = c.time(1024, 1 << 30);
        let asymptote = 2.0 * (1u64 << 30) as f64 / 70e9;
        assert!(t64 < t1024);
        assert!((t64 - asymptote).abs() / asymptote < 0.05);
        // Latency term linear in k.
        let lat_only = RingCost { alpha: 1e-6, beta: f64::INFINITY };
        assert!((lat_only.time(11, 1) - 20e-6).abs() < 1e-12);
    }

    /// The two ring halves partition the all-reduce cost exactly
    /// (`time` is defined as the doubled half, so this is bit-exact).
    #[test]
    fn halves_sum_to_all_reduce() {
        let c = RingCost { alpha: 4.4e-5, beta: 70e9 };
        for &k in &[2usize, 16, 1024] {
            for &bytes in &[4096usize, 1 << 20, 1_336_000_000] {
                let rs = c.reduce_scatter_time(k, bytes);
                let ag = c.all_gather_time(k, bytes);
                let ar = c.time(k, bytes);
                assert!(rs > 0.0 && ag > 0.0);
                assert_eq!(rs + ag, ar, "k={k} bytes={bytes}");
            }
        }
        assert_eq!(c.reduce_scatter_time(1, 1 << 20), 0.0);
        assert_eq!(c.all_gather_time(1, 1 << 20), 0.0);
    }
}
