//! Numeric precision seam: half-width storage/wire dtypes as a
//! first-class axis of the collective stack.
//!
//! The paper's headline 76-minute run is a **mixed-precision** TPU run,
//! and the 54-minute follow-up trains in fp16 with fp32 master weights
//! and dynamic loss scaling — yet until this module every byte the
//! pricing stack accounted and every element the collectives moved was a
//! 4-byte f32. [`Precision`] makes the dtype explicit:
//!
//! * **Storage/wire width** ([`Precision::bytes`]): what a parameter or
//!   gradient element occupies resident in HBM and on the interconnect —
//!   the quantity `exec::stage_split_prec` tables and
//!   `cluster::Pod` prices (half the wire for every collective at
//!   bf16/f16).
//! * **Numerics** ([`Precision::quantize`]): software bf16/f16 via bit
//!   manipulation — round-to-nearest-even, deterministic, and a pure
//!   per-element function, so quantize-on-wire reductions stay
//!   **rank-order invariant** exactly like [`super::reduce_mean`]
//!   (every rank sees the same bits regardless of arrival order).
//!   `Precision::F32` is the identity, so the f32 paths of
//!   [`reduce_mean_quant`] / [`all_gather_quant`] are bitwise-identical
//!   to the unquantized kernels by construction (they *are* the same
//!   code path).
//!
//! [`PrecisionPlan`] bundles the per-tensor choices (`[precision]`
//! config table): params dtype, grads dtype, and whether an fp32 master
//! parameter copy exists (forced on whenever params are half-width —
//! the optimizer must accumulate updates at full precision or tiny
//! steps round away; see `optim::LossScaler` for the companion
//! gradient-range machinery).

use super::{all_gather, reduce_mean, reduce_mean_mapped};

/// Storage/wire dtype of a tensor class (params or grads).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// IEEE binary32 — the baseline; quantization is the identity.
    #[default]
    F32,
    /// bfloat16: f32's exponent range, 8-bit significand. The TPU-native
    /// half type (what the paper's mixed run stores and moves).
    Bf16,
    /// IEEE binary16: 5-bit exponent, 11-bit significand. Narrow range —
    /// the dtype that makes loss scaling mandatory.
    F16,
}

impl Precision {
    /// Every dtype, smallest-width last (table/census order).
    pub const ALL: [Precision; 3] =
        [Precision::F32, Precision::Bf16, Precision::F16];

    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" | "fp32" | "float32" => Some(Precision::F32),
            "bf16" | "bfloat16" => Some(Precision::Bf16),
            "f16" | "fp16" | "float16" => Some(Precision::F16),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::F16 => "f16",
        }
    }

    /// Bytes one element occupies in storage and on the wire.
    pub fn bytes(&self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 | Precision::F16 => 2,
        }
    }

    /// Round `x` through this storage dtype (round-to-nearest-even) and
    /// widen back to f32 — the value a rank would actually read after
    /// the element crossed the wire or was stored half-width.
    ///
    /// Pure and deterministic per element; idempotent
    /// (`quantize(quantize(x)) == quantize(x)` bitwise). `F32` is the
    /// identity.
    pub fn quantize(&self, x: f32) -> f32 {
        match self {
            Precision::F32 => x,
            Precision::Bf16 => bf16_round(x),
            Precision::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
        }
    }
}

/// Round an f32 to the nearest bf16 (ties to even) and widen back:
/// round-to-nearest-even on the top 16 bits. Overflow saturates to the
/// infinity of the sign (max-f32 is above bf16's max finite + half ulp);
/// NaN stays NaN (quieted), never rounds into an infinity.
fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep the payload's top bits, force the quiet bit so the
        // truncated mantissa cannot become all-zero (which would read
        // back as an infinity).
        return f32::from_bits((bits & 0xffff_0000) | 0x0040_0000);
    }
    // Classic RNE trick: adding 0x7fff plus the round bit's own value
    // carries exactly when the tail is > half, or == half with an odd
    // kept mantissa. Infinities are fixed points (tail is zero).
    let round = 0x7fff + ((bits >> 16) & 1);
    f32::from_bits(bits.wrapping_add(round) & 0xffff_0000)
}

/// f32 -> IEEE binary16 bit pattern, round-to-nearest-even, with
/// subnormal and overflow handling (values at or above 65520 round to
/// infinity; magnitudes below 2^-25 round to signed zero).
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp32 = ((bits >> 23) & 0xff) as i32;
    let man32 = bits & 0x007f_ffff;
    if exp32 == 0xff {
        // Inf stays Inf; NaN stays (quiet) NaN.
        return if man32 == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }
    if exp32 == 0 {
        // f32 subnormals are below 2^-126 — far under half of f16's
        // smallest subnormal (2^-25): signed zero.
        return sign;
    }
    let exp = exp32 - 127 + 15; // f16-biased exponent before rounding
    let man = man32 | 0x0080_0000; // 24-bit significand, 1.23 fixed point
    // Normals keep 11 significant bits (shift 13); f16-subnormal targets
    // shift further so the unit lands on 2^-24.
    let shift = if exp <= 0 { 14 - exp } else { 13 };
    if shift > 24 {
        return sign; // the whole significand rounds away
    }
    let shift = shift as u32;
    let halfway = 1u32 << (shift - 1);
    let rem = man & ((1u32 << shift) - 1);
    let mut out = man >> shift;
    if rem > halfway || (rem == halfway && (out & 1) == 1) {
        out += 1;
    }
    if exp <= 0 {
        // Subnormal result (out <= 0x400). A carry to exactly 0x400 is
        // the smallest normal, whose bit pattern is literally sign|0x400
        // (exponent 1, mantissa 0) — the encoding composes for free.
        return sign | out as u16;
    }
    let mut exp = exp as u32;
    if out >= 0x800 {
        // Mantissa carry: 2.0 * 2^e == 1.0 * 2^(e+1).
        out >>= 1;
        exp += 1;
    }
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> Inf
    }
    sign | ((exp << 10) as u16) | ((out & 0x3ff) as u16)
}

/// IEEE binary16 bit pattern -> f32 (exact: every f16 value is
/// representable in f32).
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    if exp == 0x1f {
        return if man == 0 {
            f32::from_bits(sign | 0x7f80_0000)
        } else {
            f32::from_bits(sign | 0x7fc0_0000 | (man << 13))
        };
    }
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // signed zero
        }
        // Subnormal: man * 2^-24, exact in f32 (man has 10 bits).
        let mag = man as f32 * f32::from_bits(0x3380_0000); // 2^-24
        return if sign != 0 { -mag } else { mag };
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// [`reduce_mean`] with the wire carrying `wire`-dtype elements: every
/// per-worker contribution is rounded through the storage dtype before
/// the f64 rank-order accumulation, and the mean is rounded back into
/// the dtype the receiving buffer stores. `Precision::F32` takes the
/// unquantized kernel itself, so it is bitwise-identical to
/// [`reduce_mean`] by construction; the half dtypes remain deterministic
/// and rank-order invariant (quantization is per-element, the
/// accumulation order is unchanged).
pub fn reduce_mean_quant(wire: Precision, workers: &[&[f32]], out: &mut [f32]) {
    if wire == Precision::F32 {
        // Literally the plain kernel (identity map) — bitwise-identical
        // by construction, not by parallel implementation.
        return reduce_mean(workers, out);
    }
    reduce_mean_mapped(workers, out, |x| wire.quantize(x));
}

/// [`super::reduce_scatter_mean`] through a wire dtype — the range-local
/// half of [`reduce_mean_quant`], element-for-element bitwise equal to
/// the same range of the monolithic quantized reduction.
pub fn reduce_scatter_mean_quant(
    wire: Precision,
    workers: &[&[f32]],
    start: usize,
    end: usize,
    out: &mut [f32],
) {
    assert!(start <= end, "inverted range");
    assert_eq!(out.len(), end - start, "output length != range length");
    let slices: Vec<&[f32]> = workers
        .iter()
        .map(|w| {
            assert!(end <= w.len(), "range exceeds worker buffer");
            &w[start..end]
        })
        .collect();
    reduce_mean_quant(wire, &slices, out);
}

/// [`all_gather`] through a wire dtype: each gathered element is rounded
/// through the storage dtype. For chunks that already hold
/// storage-dtype values (the exec shards — quantization is idempotent)
/// this is a plain copy; `F32` delegates to the unquantized gather
/// bitwise.
pub fn all_gather_quant(
    wire: Precision,
    shards: &[(usize, &[f32])],
    out: &mut [f32],
) {
    if wire == Precision::F32 {
        return all_gather(shards, out);
    }
    for &(start, chunk) in shards {
        assert!(
            start + chunk.len() <= out.len(),
            "shard [{start}, {}) exceeds output length {}",
            start + chunk.len(),
            out.len()
        );
        for (o, &x) in out[start..start + chunk.len()].iter_mut().zip(chunk) {
            *o = wire.quantize(x);
        }
    }
}

/// Resolved per-tensor precision choices — the `[precision]` config
/// table as the numeric/accounting layers consume it. The derived
/// default is [`PrecisionPlan::F32`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrecisionPlan {
    /// Storage + wire dtype of the parameters (and their ZeRO-3
    /// just-in-time gathers / ZeRO-2 trailing all-gather).
    pub params: Precision,
    /// Storage + wire dtype of the gradients (and every gradient
    /// all-reduce / reduce-scatter).
    pub grads: Precision,
    /// Keep a 4-byte fp32 master parameter copy that the optimizer
    /// steps (cast back to the storage dtype afterwards). Forced on via
    /// [`PrecisionPlan::has_master`] whenever params are half-width.
    pub master_weights: bool,
    /// Gradient *wire* override (`[precision] grads_wire`): what the
    /// gradient collectives actually ship. `None` derives the wire from
    /// the gradient storage dtype (the pre-compression behavior);
    /// `Some(Wire::F8 | Wire::OneBit)` turns on error-feedback
    /// compressed collectives, which add fp32 residual state priced by
    /// the cluster model.
    pub grads_wire: Option<super::compress::Wire>,
    /// Per-segment storage override (`[precision] norms_fp32`): keep
    /// the no-decay segments — layer norms and biases, the LM-head bias
    /// included — resident in fp32 even when `params` is half-width.
    /// Those segments are tiny (a few KB against the ~1.3 GB of BERT
    /// weight matrices), so the wire/storage accounting ignores them,
    /// but their *numerics* skip the quantize-back-to-storage cast: the
    /// norm statistics step at full precision.
    pub norms_fp32: bool,
}

impl PrecisionPlan {
    /// The all-f32 baseline: no master copy, every path bitwise-
    /// identical to the pre-precision stack.
    pub const F32: PrecisionPlan = PrecisionPlan {
        params: Precision::F32,
        grads: Precision::F32,
        master_weights: false,
        grads_wire: None,
        norms_fp32: false,
    };

    /// The paper's mixed recipe: half-width params + grads (storage and
    /// wire), fp32 master weights.
    pub fn mixed(half: Precision) -> PrecisionPlan {
        PrecisionPlan {
            params: half,
            grads: half,
            master_weights: true,
            grads_wire: None,
            norms_fp32: false,
        }
    }

    /// Same plan with the fp32 norm/bias storage override on.
    pub fn with_norms_fp32(mut self, on: bool) -> PrecisionPlan {
        self.norms_fp32 = on;
        self
    }

    /// Same plan with an explicit gradient wire format.
    pub fn with_grads_wire(mut self, wire: super::compress::Wire) -> PrecisionPlan {
        self.grads_wire = Some(wire);
        self
    }

    /// The resolved gradient wire format: the explicit override, or the
    /// gradient storage dtype when none is configured.
    pub fn wire(&self) -> super::compress::Wire {
        self.grads_wire
            .unwrap_or_else(|| super::compress::Wire::from_precision(self.grads))
    }

    /// True when the gradient wire is one of the compressed formats and
    /// therefore carries error-feedback residual state.
    pub fn compressed_wire(&self) -> bool {
        self.wire().is_compressed()
    }

    /// Bytes on the wire for `elems` gradient elements under the
    /// resolved wire format (per-chunk scale metadata included) — what
    /// the pod model prices gradient collectives at.
    pub fn grad_wire_payload_bytes(&self, elems: usize) -> usize {
        self.wire().payload_bytes(elems)
    }

    /// Anything half-width anywhere?
    pub fn is_mixed(&self) -> bool {
        self.params != Precision::F32 || self.grads != Precision::F32
    }

    /// Whether an fp32 master parameter copy exists: explicit opt-in, or
    /// forced by half-width params (the optimizer must accumulate at
    /// full precision).
    pub fn has_master(&self) -> bool {
        self.master_weights || self.params != Precision::F32
    }

    /// Bytes per parameter element in storage / on the wire.
    pub fn param_bytes(&self) -> usize {
        self.params.bytes()
    }

    /// Bytes per gradient element in storage / on the wire.
    pub fn grad_bytes(&self) -> usize {
        self.grads.bytes()
    }

    /// Bytes per element of the fp32 master copy (0 when none exists).
    pub fn master_bytes(&self) -> usize {
        if self.has_master() {
            4
        } else {
            0
        }
    }

    /// Short table label, e.g. `f32`, `bf16/bf16+master`, or
    /// `bf16/bf16+master+1bit-wire` when a compressed wire is configured.
    pub fn label(&self) -> String {
        let mut s = if !self.is_mixed() && !self.has_master() {
            self.params.as_str().to_string()
        } else {
            let mut s =
                format!("{}/{}", self.params.as_str(), self.grads.as_str());
            if self.has_master() {
                s.push_str("+master");
            }
            s
        };
        if self.compressed_wire() {
            s.push_str(&format!("+{}-wire", self.wire().as_str()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::REDUCE_CHUNK;
    use crate::util::Rng;

    #[test]
    fn parse_roundtrip_and_bytes() {
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.as_str()), Some(p));
        }
        assert_eq!(Precision::parse("fp16"), Some(Precision::F16));
        assert_eq!(Precision::parse("bfloat16"), Some(Precision::Bf16));
        assert_eq!(Precision::parse("fp8"), None);
        assert_eq!(Precision::F32.bytes(), 4);
        assert_eq!(Precision::Bf16.bytes(), 2);
        assert_eq!(Precision::F16.bytes(), 2);
    }

    #[test]
    fn f32_quantize_is_identity_bitwise() {
        let mut rng = Rng::new(41);
        for _ in 0..1000 {
            let x = rng.normal_f32(1e10);
            assert_eq!(Precision::F32.quantize(x).to_bits(), x.to_bits());
        }
        for x in [0.0f32, -0.0, f32::INFINITY, f32::MIN_POSITIVE, f32::MAX] {
            assert_eq!(Precision::F32.quantize(x).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn bf16_known_values_and_rne() {
        let q = |x: f32| Precision::Bf16.quantize(x);
        // Exactly representable values are fixed points.
        for x in [0.0f32, 1.0, -2.5, 256.0, 3.0e38, -1.0e-30] {
            let once = q(x);
            assert_eq!(q(once).to_bits(), once.to_bits(), "{x}");
        }
        assert_eq!(q(1.0), 1.0);
        assert_eq!(q(-0.0).to_bits(), (-0.0f32).to_bits());
        // bf16 ulp at 1.0 is 2^-7 = 0.0078125. Exactly halfway
        // (1.00390625) ties to the even mantissa -> 1.0.
        assert_eq!(q(1.00390625), 1.0);
        // One bit above the tie rounds up.
        assert_eq!(q(f32::from_bits(0x3f80_8001)), 1.0078125);
        // Three quarters of an ulp rounds up too.
        assert_eq!(q(1.005859375), 1.0078125);
        // The next tie (1.01171875, kept mantissa odd) rounds away.
        assert_eq!(q(1.01171875), 1.015625);
        // Infinities are fixed points; f32::MAX overflows to +inf.
        assert_eq!(q(f32::INFINITY), f32::INFINITY);
        assert_eq!(q(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert_eq!(q(f32::MAX), f32::INFINITY);
        assert_eq!(q(f32::MIN), f32::NEG_INFINITY);
        // NaN stays NaN (never becomes an infinity).
        assert!(q(f32::NAN).is_nan());
        assert!(q(f32::from_bits(0x7f80_0001)).is_nan());
    }

    #[test]
    fn f16_known_values() {
        let q = |x: f32| Precision::F16.quantize(x);
        assert_eq!(q(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(q(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(q(1.0), 1.0);
        assert_eq!(q(-1.5), -1.5);
        assert_eq!(q(65504.0), 65504.0); // f16 max finite
        assert_eq!(q(65519.9), 65504.0); // below the rounding boundary
        assert_eq!(q(65520.0), f32::INFINITY); // ties away to inf
        assert_eq!(q(-65520.0), f32::NEG_INFINITY);
        assert_eq!(q(f32::INFINITY), f32::INFINITY);
        assert!(q(f32::NAN).is_nan());
        // Smallest f16 normal and subnormal.
        assert_eq!(q(6.103515625e-5), 6.103515625e-5); // 2^-14
        assert_eq!(q(5.9604644775390625e-8), 5.9604644775390625e-8); // 2^-24
        // Below half the smallest subnormal: rounds to signed zero.
        assert_eq!(q(1.0e-8).to_bits(), 0.0f32.to_bits());
        assert_eq!(q(-1.0e-8).to_bits(), (-0.0f32).to_bits());
        // f16 ulp at 1.0 is 2^-10; halfway ties to even -> 1.0, one f32
        // bit above the tie rounds up.
        assert_eq!(q(1.0 + 0.00048828125), 1.0);
        assert_eq!(q(f32::from_bits(0x3f80_1001)), 1.0009765625);
        // Subnormal rounding: 1.5 * 2^-24 ties to even -> 2^-24 * 2.
        let sub = f16_bits_to_f32(0x0002);
        assert_eq!(q(1.5 * 5.9604644775390625e-8), sub);
    }

    /// Quantization is idempotent for both half dtypes on random values
    /// across the full exponent range — the storage-dtype fixed-point
    /// property the exec shards rely on (a stored value re-crossing the
    /// wire is bit-identical).
    #[test]
    #[cfg_attr(miri, ignore)] // 4000 random roundtrips: minutes under Miri
    fn quantize_idempotent_on_random_values() {
        let mut rng = Rng::new(42);
        for p in [Precision::Bf16, Precision::F16] {
            for _ in 0..2000 {
                let scale = 10.0f32.powi((rng.below(60) as i32) - 30);
                let x = rng.normal_f32(scale);
                let once = p.quantize(x);
                let twice = p.quantize(once);
                assert_eq!(
                    once.to_bits(),
                    twice.to_bits(),
                    "{p:?} x={x} once={once}"
                );
                // sign preserved, and the rounded value is within one
                // ulp-ish relative distance for in-range normals
                if x.is_finite() && once.is_finite() && once != 0.0 {
                    assert_eq!(once.is_sign_negative(), x.is_sign_negative());
                }
            }
        }
    }

    /// f16 roundtrip is exact over every one of the 65536 bit patterns:
    /// widen-then-narrow returns the original bits (modulo NaN
    /// quieting).
    #[test]
    #[cfg_attr(miri, ignore)] // 65536-pattern sweep: minutes under Miri
    fn f16_all_bit_patterns_roundtrip() {
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            let back = f32_to_f16_bits(x);
            if x.is_nan() {
                // NaNs stay NaNs; payload may quiet.
                assert_eq!(back & 0x7c00, 0x7c00);
                assert_ne!(back & 0x03ff, 0);
            } else {
                assert_eq!(back, h, "h={h:#06x} x={x}");
            }
        }
    }

    #[test]
    fn quantized_reduce_f32_is_bitwise_reduce_mean() {
        let mut rng = Rng::new(43);
        let n = REDUCE_CHUNK + 57;
        let bufs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..n).map(|_| rng.normal_f32(2.0)).collect())
            .collect();
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let mut plain = vec![0.0f32; n];
        reduce_mean(&refs, &mut plain);
        let mut quant = vec![0.0f32; n];
        reduce_mean_quant(Precision::F32, &refs, &mut quant);
        for i in 0..n {
            assert_eq!(plain[i].to_bits(), quant[i].to_bits(), "i={i}");
        }
    }

    /// The quantized reduction equals the definitional per-element
    /// model — quantize every contribution, average in f64 worker
    /// order, quantize the mean — and its scatter half reproduces the
    /// monolithic result range-exactly (rank-order invariance is
    /// inherited from the unchanged accumulation order).
    #[test]
    fn quantized_reduce_matches_reference_and_scatter() {
        let mut rng = Rng::new(44);
        for wire in [Precision::Bf16, Precision::F16] {
            let n = 513;
            let k = 3;
            let bufs: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.normal_f32(1.5)).collect())
                .collect();
            let refs: Vec<&[f32]> =
                bufs.iter().map(|b| b.as_slice()).collect();
            let mut got = vec![0.0f32; n];
            reduce_mean_quant(wire, &refs, &mut got);
            let inv = 1.0f64 / k as f64;
            for i in 0..n {
                let mut acc = 0.0f64;
                for w in &refs {
                    acc += wire.quantize(w[i]) as f64;
                }
                let want = wire.quantize((acc * inv) as f32);
                assert_eq!(got[i].to_bits(), want.to_bits(), "{wire:?} i={i}");
                // the result is a storage-dtype value
                assert_eq!(
                    wire.quantize(got[i]).to_bits(),
                    got[i].to_bits()
                );
            }
            // scatter half == the same range of the monolithic reduce
            let mut shard = vec![0.0f32; 100];
            reduce_scatter_mean_quant(wire, &refs, 37, 137, &mut shard);
            for (j, &v) in shard.iter().enumerate() {
                assert_eq!(v.to_bits(), got[37 + j].to_bits());
            }
        }
    }

    #[test]
    fn quantized_gather_copies_storage_values_exactly() {
        let mut rng = Rng::new(45);
        for wire in [Precision::F32, Precision::Bf16, Precision::F16] {
            let n = 64;
            let raw: Vec<f32> = (0..n).map(|_| rng.normal_f32(3.0)).collect();
            let stored: Vec<f32> =
                raw.iter().map(|&x| wire.quantize(x)).collect();
            let mut out = vec![0.0f32; n];
            all_gather_quant(
                wire,
                &[(0, &stored[..40]), (40, &stored[40..])],
                &mut out,
            );
            for i in 0..n {
                assert_eq!(out[i].to_bits(), stored[i].to_bits(), "i={i}");
            }
        }
    }

    #[test]
    fn plan_flags_and_bytes() {
        let f = PrecisionPlan::F32;
        assert!(!f.is_mixed() && !f.has_master());
        assert_eq!((f.param_bytes(), f.grad_bytes(), f.master_bytes()), (4, 4, 0));
        assert_eq!(f.label(), "f32");
        let m = PrecisionPlan::mixed(Precision::Bf16);
        assert!(m.is_mixed() && m.has_master());
        assert_eq!((m.param_bytes(), m.grad_bytes(), m.master_bytes()), (2, 2, 4));
        assert_eq!(m.label(), "bf16/bf16+master");
        // half params force the master copy even if the flag is off
        let forced = PrecisionPlan {
            params: Precision::F16,
            grads: Precision::F32,
            master_weights: false,
            grads_wire: None,
            norms_fp32: false,
        };
        assert!(forced.has_master());
        assert_eq!(forced.master_bytes(), 4);
        // f32 params + explicit master is allowed (pure opt-in)
        let optin = PrecisionPlan {
            params: Precision::F32,
            grads: Precision::Bf16,
            master_weights: true,
            grads_wire: None,
            norms_fp32: false,
        };
        assert!(optin.has_master() && optin.is_mixed());
        assert_eq!(PrecisionPlan::default(), PrecisionPlan::F32);
        // The wire derives from grad storage until overridden.
        use crate::collective::Wire;
        assert_eq!(f.wire(), Wire::F32);
        assert_eq!(m.wire(), Wire::Bf16);
        let compressed = m.with_grads_wire(Wire::OneBit);
        assert_eq!(compressed.wire(), Wire::OneBit);
        assert!(compressed.compressed_wire() && !m.compressed_wire());
        assert_eq!(compressed.label(), "bf16/bf16+master+1bit-wire");
        assert_eq!(compressed.grad_wire_payload_bytes(1024), 128 + 8);
        // Storage bytes (residents) are unaffected by the wire override.
        assert_eq!(compressed.grad_bytes(), 2);
    }
}
