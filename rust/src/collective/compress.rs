//! Compressed gradient wire formats with error feedback.
//!
//! [`Wire`] extends the storage dtypes of [`Precision`] with two compressed
//! widths that exist only on the wire (gradients are always *stored* at a
//! `Precision` dtype; the wire format decides what the collectives ship):
//!
//! * `Wire::F8` — E4M3 (1 sign, 4 exponent bits, 3 mantissa bits, bias 7,
//!   max finite 448, no infinities). Deterministic round-to-nearest-even,
//!   same contract as the bf16/f16 software codecs in `precision`.
//! * `Wire::OneBit` — sign bit per element plus one fp32 scale per
//!   [`ONEBIT_CHUNK`]-element chunk (`scale = mean |v|` over the chunk),
//!   ~1/30 the bytes of f32 including the scale metadata.
//!
//! Both are lossy enough to wreck an optimizer trajectory if applied
//! naively, so they ship as **error-feedback** collectives (1-bit
//! Adam/LAMB style): every sender keeps a persistent fp32 residual `r`,
//! quantizes `v = g + r`, transmits `t = Q(v)`, and stores back
//! `r' = v - t`. The quantization errors telescope, so the compressed
//! reduce is unbiased over steps even though each step is biased.
//!
//! The reduce itself is two-stage, mirroring where state lives on a pod:
//!
//! * **stage A (send)** — each worker quantizes its error-compensated
//!   contribution with its own full-length residual (replicated state:
//!   one residual per worker regardless of ZeRO stage);
//! * **stage B (recv)** — the f64 worker-order mean of the transmitted
//!   values is itself quantized back to the wire format at the reduce
//!   site, with a second residual owned by whoever owns the reduced
//!   bucket (dense: every rank holds the same copy; zero2/3: it shards
//!   with the gradient owner).
//!
//! Contracts inherited from the rest of the collective stack:
//!
//! * **Deterministic**: accumulation is f64 in worker-index order; the
//!   1-bit chunk scale is an f64 mean in element order. No atomics, no
//!   arrival-order dependence.
//! * **Offset-aligned**: 1-bit chunk boundaries are defined on *global*
//!   element indices (`offset` = the bucket's start in the flat gradient),
//!   so a bucket reduced dense and the same bucket reduce-scattered under
//!   zero2/3 chunk identically — dense and sharded modes stay bitwise
//!   equal at every wire width.
//! * **Non-finite passthrough**: a non-finite `v` (or a 1-bit chunk whose
//!   scale overflows) is transmitted raw and the residual update is
//!   skipped, so the loss-scaler gate still observes the non-finite value
//!   and residuals are never poisoned.
//! * **F32 wire is the plain kernel**: `reduce_mean_ef` at `Wire::F32`
//!   delegates to [`crate::collective::reduce_mean`] bit for bit.
//!
//! The inner loops are written as chunked, branch-light passes over fixed
//! ranges (the same shape as `REDUCE_CHUNK` in `collective::mod`) so LLVM
//! can autovectorize them; `benches/bench_allreduce.rs` measures them
//! against element-at-a-time scalar baselines and asserts bitwise
//! equality.

use super::precision::{reduce_mean_quant, Precision};
use super::{reduce_mean, REDUCE_CHUNK};

/// Elements per 1-bit scale chunk. One fp32 scale is shipped per chunk, so
/// the payload is `n/8 + 4*ceil(n/512)` bytes — ~1.03 bits/element. Chunk
/// boundaries are aligned to global element indices (see module docs).
pub const ONEBIT_CHUNK: usize = 512;

/// Gradient wire format: what the collectives ship, independent of the
/// storage dtype. The first three variants are exactly the `Precision`
/// dtypes; the last two exist only on the wire.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Wire {
    #[default]
    F32,
    Bf16,
    F16,
    /// E4M3 fp8: RNE quantize per element, 1 byte each.
    F8,
    /// Sign per element + fp32 scale per [`ONEBIT_CHUNK`] chunk.
    OneBit,
}

impl Wire {
    pub const ALL: [Wire; 5] = [Wire::F32, Wire::Bf16, Wire::F16, Wire::F8, Wire::OneBit];

    /// Parse a config spelling. Accepts the `Precision` spellings plus
    /// `"f8"`/`"e4m3"` and `"1bit"`/`"onebit"`.
    pub fn parse(s: &str) -> Option<Wire> {
        match s.to_ascii_lowercase().as_str() {
            "f8" | "fp8" | "e4m3" | "float8" => Some(Wire::F8),
            "1bit" | "onebit" | "1-bit" | "one_bit" => Some(Wire::OneBit),
            other => Precision::parse(other).map(Wire::from_precision),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Wire::F32 => "f32",
            Wire::Bf16 => "bf16",
            Wire::F16 => "f16",
            Wire::F8 => "f8",
            Wire::OneBit => "1bit",
        }
    }

    pub fn from_precision(p: Precision) -> Wire {
        match p {
            Precision::F32 => Wire::F32,
            Precision::Bf16 => Wire::Bf16,
            Precision::F16 => Wire::F16,
        }
    }

    /// True for the wire-only compressed formats (f8 / 1-bit) that carry
    /// error-feedback residual state.
    pub fn is_compressed(&self) -> bool {
        matches!(self, Wire::F8 | Wire::OneBit)
    }

    /// Bytes on the wire for `elems` gradient elements, including the
    /// per-chunk scale metadata for the 1-bit format. For the uncompressed
    /// widths this is exactly `elems * dtype_bytes`, so pod-model pricing
    /// is unchanged when no compression is configured.
    pub fn payload_bytes(&self, elems: usize) -> usize {
        match self {
            Wire::F32 => elems * 4,
            Wire::Bf16 | Wire::F16 => elems * 2,
            Wire::F8 => elems,
            Wire::OneBit => elems.div_ceil(8) + 4 * elems.div_ceil(ONEBIT_CHUNK),
        }
    }

    /// Quantize a single value through this wire format. For `OneBit` this
    /// is undefined without chunk context and panics; use [`ef_transmit`].
    pub fn quantize(&self, x: f32) -> f32 {
        match self {
            Wire::F32 => x,
            Wire::Bf16 => Precision::Bf16.quantize(x),
            Wire::F16 => Precision::F16.quantize(x),
            Wire::F8 => f8_bits_to_f32(f32_to_f8_bits(x)),
            Wire::OneBit => panic!("1-bit wire quantizes per chunk, not per element"),
        }
    }
}

// ---------------------------------------------------------------------------
// E4M3 codec
// ---------------------------------------------------------------------------
//
// Same structure as the f16 codec in `precision`: extract sign/exponent/
// mantissa, rebias, shift with round-to-nearest-even on the dropped bits,
// handle the carry-out. E4M3 departs from IEEE in two ways: there is no
// infinity (the 0x7f mantissa pattern at max exponent is NaN, everything
// else at e=15 is finite up to 448), and finite overflow *saturates* to
// ±448 rather than producing a non-finite — gradients at the wire edge
// clip instead of detonating the loss-scaler gate. f32 Inf/NaN still map
// to the NaN pattern so non-finiteness is preserved end to end.

/// f32 -> E4M3 bits with round-to-nearest-even. Deterministic, no FPU
/// rounding-mode dependence.
pub(crate) fn f32_to_f8_bits(x: f32) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 24) & 0x80) as u8;
    let exp32 = ((bits >> 23) & 0xff) as i32;
    let man32 = bits & 0x007f_ffff;
    if exp32 == 0xff {
        // Inf and NaN: E4M3 has a single NaN pattern per sign and no Inf.
        return sign | 0x7f;
    }
    if exp32 == 0 {
        // f32 subnormals are < 2^-126, far below half the smallest f8
        // subnormal (2^-10) — they all round to signed zero.
        return sign;
    }
    let exp = exp32 - 127 + 7; // f8-biased exponent
    let man = man32 | 0x0080_0000; // make the leading 1 explicit (24 bits)
    // Normals keep 4 significant bits (23 - 3 = shift 20); subnormal
    // results shift further so the integer result is in units of 2^-9,
    // the f8 subnormal ulp.
    let shift = if exp <= 0 { 21 - exp } else { 20 };
    if shift > 24 {
        return sign; // too small to round even to the smallest subnormal
    }
    let shift = shift as u32;
    let halfway = 1u32 << (shift - 1);
    let rem = man & ((1u32 << shift) - 1);
    let mut out = man >> shift;
    if rem > halfway || (rem == halfway && (out & 1) == 1) {
        out += 1;
    }
    if exp <= 0 {
        // Subnormal result; a carry to 0x8 is exactly the smallest normal
        // (exponent field 1), which the encoding below composes naturally.
        return sign | out as u8;
    }
    let mut exp = exp as u32;
    if out >= 0x10 {
        out >>= 1;
        exp += 1;
    }
    if exp > 15 || (exp == 15 && out & 0x7 == 0x7) {
        // Finite overflow (above 448, or rounding into the NaN pattern):
        // saturate to the max finite magnitude.
        return sign | 0x7e;
    }
    sign | ((exp << 3) as u8) | ((out & 0x7) as u8)
}

/// E4M3 bits -> f32 (exact: every finite f8 value is representable).
pub(crate) fn f8_bits_to_f32(b: u8) -> f32 {
    let sign = ((b & 0x80) as u32) << 24;
    let exp = ((b >> 3) & 0x0f) as u32;
    let man = (b & 0x07) as u32;
    if exp == 0x0f && man == 0x07 {
        return f32::from_bits(sign | 0x7fc0_0000); // the NaN pattern
    }
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // signed zero
        }
        // Subnormal: man * 2^-9, renormalized into an f32 normal.
        let mag = man as f32 * f32::from_bits(0x3b00_0000); // 2^-9
        return f32::from_bits(mag.to_bits() | sign);
    }
    f32::from_bits(sign | ((exp + 120) << 23) | (man << 20))
}

// ---------------------------------------------------------------------------
// Error-feedback transmit (stage A / stage B quantizer)
// ---------------------------------------------------------------------------

/// Quantize one sender's contribution into its transmitted form.
///
/// `t[i] = Q(g[i] + r[i])` and `r[i] = (g[i] + r[i]) - t[i]` when a
/// residual is supplied; without one this is plain quantization of `g`.
/// `offset` is the global element index of `g[0]`, anchoring the 1-bit
/// chunk grid. Non-finite values pass through untouched and skip the
/// residual update (for 1-bit, the whole affected chunk passes through,
/// since its scale is poisoned).
///
/// This is the single quantization site for both EF stages: stage A calls
/// it per worker with the send residual, stage B calls it on the f64 mean
/// with the recv residual.
pub fn ef_transmit(wire: Wire, offset: usize, g: &[f32], residual: Option<&mut [f32]>, t: &mut [f32]) {
    assert_eq!(g.len(), t.len(), "transmit buffer length mismatch");
    if let Some(r) = &residual {
        assert_eq!(g.len(), r.len(), "residual length mismatch");
    }
    match wire {
        Wire::F32 => t.copy_from_slice(g),
        Wire::Bf16 | Wire::F16 | Wire::F8 => {
            let q = |x: f32| wire.quantize(x);
            match residual {
                Some(r) => {
                    for ((t, &g), r) in t.iter_mut().zip(g).zip(r.iter_mut()) {
                        let v = g + *r;
                        if v.is_finite() {
                            let out = q(v);
                            *t = out;
                            *r = v - out;
                        } else {
                            *t = v;
                        }
                    }
                }
                None => {
                    for (t, &g) in t.iter_mut().zip(g) {
                        *t = if g.is_finite() { q(g) } else { g };
                    }
                }
            }
        }
        Wire::OneBit => {
            let mut residual = residual;
            let mut i = 0;
            while i < g.len() {
                let gidx = offset + i;
                let cend = (gidx / ONEBIT_CHUNK + 1) * ONEBIT_CHUNK;
                let len = (cend - gidx).min(g.len() - i);
                let r = residual.as_deref_mut().map(|r| &mut r[i..i + len]);
                one_bit_chunk(&g[i..i + len], r, &mut t[i..i + len]);
                i += len;
            }
        }
    }
}

/// One 1-bit chunk: scale = f64 mean of |v| over the chunk, transmit
/// `±scale` by sign of `v`. Two branch-light passes so the compiler can
/// vectorize the |v| accumulation and the sign-select store.
fn one_bit_chunk(g: &[f32], residual: Option<&mut [f32]>, t: &mut [f32]) {
    // Pass 1: v = g + r into t (t doubles as the v scratch), f64 |v| sum.
    let mut sum = 0.0f64;
    match &residual {
        Some(r) => {
            for ((t, &g), &r) in t.iter_mut().zip(g).zip(r.iter()) {
                let v = g + r;
                *t = v;
                sum += (v as f64).abs();
            }
        }
        None => {
            for (t, &g) in t.iter_mut().zip(g) {
                *t = g;
                sum += (g as f64).abs();
            }
        }
    }
    let scale = (sum / g.len() as f64) as f32;
    if !scale.is_finite() {
        // A non-finite v poisoned the chunk scale: transmit the raw values
        // (already in t) and leave the residual alone.
        return;
    }
    // Pass 2: sign-select ±scale, residual picks up the difference.
    match residual {
        Some(r) => {
            for (t, r) in t.iter_mut().zip(r.iter_mut()) {
                let v = *t;
                let q = if v.is_sign_negative() { -scale } else { scale };
                *t = q;
                *r = v - q;
            }
        }
        None => {
            for t in t.iter_mut() {
                let v = *t;
                *t = if v.is_sign_negative() { -scale } else { scale };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Compressed reduce kernels
// ---------------------------------------------------------------------------

/// Error-feedback residual buffers for one reduce call: one full-range
/// send residual per worker (stage A) plus the recv residual owned by the
/// reduce site (stage B). Both slices cover exactly the reduced range.
pub struct EfResiduals<'a, 'b> {
    pub send: &'a mut [&'b mut [f32]],
    pub recv: &'a mut [f32],
}

/// Compressed mean-reduce with error feedback.
///
/// `out[i] = Q_B( mean_w Q_A(workers[w][i] + r_send[w][i]) + r_recv[i] )`
/// with the f64 worker-index-order mean of `reduce_mean`, and both
/// quantization stages updating their residuals. With `residuals = None`
/// (error feedback off) both stages quantize without residual state —
/// the shape the convergence regression test shows drifting.
///
/// `offset` is the global element index of `out[0]` (1-bit chunk grid);
/// `Wire::F32` is bitwise the plain `reduce_mean`, and bf16/f16 are
/// bitwise `reduce_mean_quant` — residuals are never touched for
/// uncompressed wires.
pub fn reduce_mean_ef(
    wire: Wire,
    offset: usize,
    workers: &[&[f32]],
    residuals: Option<EfResiduals<'_, '_>>,
    out: &mut [f32],
) {
    match wire {
        Wire::F32 => return reduce_mean(workers, out),
        Wire::Bf16 => return reduce_mean_quant(Precision::Bf16, workers, out),
        Wire::F16 => return reduce_mean_quant(Precision::F16, workers, out),
        Wire::F8 | Wire::OneBit => {}
    }
    let n = out.len();
    let k = workers.len();
    assert!(k > 0, "reduce over zero workers");
    for w in workers {
        assert_eq!(w.len(), n, "worker grad length mismatch");
    }
    let (mut send, recv) = match residuals {
        Some(ef) => {
            assert_eq!(ef.send.len(), k, "one send residual per worker");
            assert_eq!(ef.recv.len(), n, "recv residual length mismatch");
            (Some(ef.send), Some(ef.recv))
        }
        None => (None, None),
    };
    // Stage A + mean: quantize each worker's compensated contribution and
    // accumulate it in f64, strictly in worker-index order.
    let mut acc = vec![0.0f64; n];
    let mut scratch = vec![0.0f32; n];
    for (w, grads) in workers.iter().enumerate() {
        let r = send.as_deref_mut().map(|s| &mut *s[w]);
        ef_transmit(wire, offset, grads, r, &mut scratch);
        accumulate_f64(&mut acc, &scratch);
    }
    let inv = 1.0 / k as f64;
    for (s, a) in scratch.iter_mut().zip(acc.iter()) {
        *s = (a * inv) as f32;
    }
    // Stage B: the mean goes back onto the wire, compensated by the recv
    // residual owned by whoever owns this range.
    ef_transmit(wire, offset, &scratch, recv, out);
}

/// Chunked f64 accumulation (`acc[i] += x[i]`), blocked like REDUCE_CHUNK
/// so the widening add vectorizes.
fn accumulate_f64(acc: &mut [f64], x: &[f32]) {
    for (ac, xc) in acc.chunks_mut(REDUCE_CHUNK).zip(x.chunks(REDUCE_CHUNK)) {
        for (a, &v) in ac.iter_mut().zip(xc) {
            *a += v as f64;
        }
    }
}

/// Copy wire-formed shard values into the dense output: the all-gather
/// counterpart of [`reduce_mean_ef`]. Values coming out of stage B are
/// already in the wire format, so gathering them is a plain copy for the
/// compressed wires (re-quantizing f8 is idempotent; 1-bit values are
/// `±scale` f32s that only the reduce site could re-chunk). Uncompressed
/// wires keep the `all_gather_quant` behavior.
pub fn all_gather_wire(wire: Wire, shards: &[(usize, &[f32])], out: &mut [f32]) {
    match wire {
        Wire::F32 | Wire::F8 | Wire::OneBit => {
            for &(start, shard) in shards {
                out[start..start + shard.len()].copy_from_slice(shard);
            }
        }
        Wire::Bf16 | Wire::F16 => {
            let p = match wire {
                Wire::Bf16 => Precision::Bf16,
                _ => Precision::F16,
            };
            super::precision::all_gather_quant(p, shards, out);
        }
    }
}

// ---------------------------------------------------------------------------
// Vectorized storage quantizer (bitwise-identical to the scalar codec)
// ---------------------------------------------------------------------------

/// Branchless bf16 RNE round: same bits as `precision::bf16_round` (which
/// early-returns on NaN), but written as straight-line bit arithmetic with
/// a select so the whole loop body vectorizes.
#[inline(always)]
fn bf16_round_branchless(x: f32) -> f32 {
    let bits = x.to_bits();
    let nan = (bits & 0x7fff_ffff) > 0x7f80_0000;
    let nan_bits = (bits & 0xffff_0000) | 0x0040_0000;
    let rounded = bits.wrapping_add(0x7fff + ((bits >> 16) & 1)) & 0xffff_0000;
    f32::from_bits(if nan { nan_bits } else { rounded })
}

/// Quantize a slice in place through a `Precision` dtype. Bitwise-identical
/// to mapping `p.quantize` per element, but the bf16 path uses the
/// branchless round above and all paths run as chunked inner loops —
/// `bench_allreduce` carries the scalar-vs-chunked rows proving the
/// speedup and the bitwise match.
pub fn quantize_slice(p: Precision, buf: &mut [f32]) {
    match p {
        Precision::F32 => {}
        Precision::Bf16 => {
            for chunk in buf.chunks_mut(REDUCE_CHUNK) {
                for x in chunk.iter_mut() {
                    *x = bf16_round_branchless(*x);
                }
            }
        }
        Precision::F16 => {
            for chunk in buf.chunks_mut(REDUCE_CHUNK) {
                for x in chunk.iter_mut() {
                    *x = Precision::F16.quantize(*x);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f8_all_bit_patterns_roundtrip() {
        // Every E4M3 bit pattern decodes to an f32 that encodes back to
        // the same bits — including both signed zeros, all subnormals,
        // the 448 endpoints, and the NaN pattern.
        for b in 0..=u8::MAX {
            let x = f8_bits_to_f32(b);
            let back = f32_to_f8_bits(x);
            assert_eq!(back, b, "pattern {b:#04x} -> {x} -> {back:#04x}");
        }
    }

    #[test]
    fn f8_known_values() {
        assert_eq!(f8_bits_to_f32(0x7e), 448.0);
        assert_eq!(f8_bits_to_f32(0xfe), -448.0);
        assert_eq!(f8_bits_to_f32(0x01), f32::from_bits(0x3b00_0000)); // 2^-9
        assert_eq!(f8_bits_to_f32(0x08), 0.015625); // 2^-6, smallest normal
        assert_eq!(f8_bits_to_f32(0x38), 1.0);
        assert_eq!(f8_bits_to_f32(0x39), 1.125);
        assert!(f8_bits_to_f32(0x7f).is_nan());
        assert!(f8_bits_to_f32(0xff).is_nan());
        assert_eq!(f8_bits_to_f32(0x00).to_bits(), 0.0f32.to_bits());
        assert_eq!(f8_bits_to_f32(0x80).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f8_quantize_rounds_saturates_and_preserves_nonfinite() {
        let q = |x: f32| Wire::F8.quantize(x);
        assert_eq!(q(1.0), 1.0);
        assert_eq!(q(1.05), 1.0); // nearest of {1.0, 1.125}
        assert_eq!(q(1.0625), 1.0); // tie -> even mantissa (1.0)
        assert_eq!(q(1.1875), 1.25); // tie -> even mantissa (1.25)
        assert_eq!(q(447.0), 448.0);
        assert_eq!(q(1.0e6), 448.0); // finite overflow saturates
        assert_eq!(q(-1.0e6), -448.0);
        assert_eq!(q(460.0), 448.0); // below the 464 midpoint
        assert_eq!(q(470.0), 448.0); // would round into the NaN pattern
        assert_eq!(q(464.0), 448.0); // exact tie -> even mantissa (448)
        assert!(q(f32::INFINITY).is_nan()); // non-finite stays non-finite
        assert!(q(f32::NAN).is_nan());
        assert_eq!(q(1.0e-12), 0.0); // underflow to signed zero
        assert_eq!(q(-1.0e-12).to_bits(), (-0.0f32).to_bits());
        // RNE at the subnormal boundary: 2^-10 is halfway between 0 and
        // the smallest subnormal 2^-9; ties go to the even mantissa (0).
        assert_eq!(q(f32::from_bits(0x3a80_0000)), 0.0);
    }

    #[test]
    fn f8_monotone_on_finite_grid() {
        // Decoded finite values are strictly increasing with the bit
        // pattern within each sign, which the codec relies on for RNE.
        let mut prev = f8_bits_to_f32(0x00);
        for b in 1..0x7f {
            let x = f8_bits_to_f32(b);
            assert!(x > prev, "non-monotone at {b:#04x}");
            prev = x;
        }
    }

    #[test]
    fn one_bit_chunk_scale_is_mean_abs_and_residual_reconstructs() {
        // Dyadic data: |v| ∈ {1, 3} -> scale 2.0, every subtraction exact.
        let g = [1.0f32, -3.0, 3.0, -1.0];
        let mut r = [0.0f32; 4];
        let mut t = [0.0f32; 4];
        ef_transmit(Wire::OneBit, 0, &g, Some(&mut r), &mut t);
        assert_eq!(t, [2.0, -2.0, 2.0, -2.0]);
        assert_eq!(r, [-1.0, -1.0, 1.0, 1.0]);
        for i in 0..4 {
            assert_eq!(t[i] + r[i], g[i], "residual + transmit reconstructs");
        }
    }

    #[test]
    fn one_bit_chunks_align_to_global_offset() {
        // A range starting mid-chunk must split at the global boundary:
        // offset 510 with 4 elements -> chunks [510,512) and [512,514).
        let g = [1.0f32, 3.0, 5.0, 7.0];
        let mut t = [0.0f32; 4];
        ef_transmit(Wire::OneBit, 510, &g, None, &mut t);
        assert_eq!(t, [2.0, 2.0, 6.0, 6.0]);
        // Same data at an aligned offset is one chunk of mean 4.
        ef_transmit(Wire::OneBit, 512, &g, None, &mut t);
        assert_eq!(t, [4.0, 4.0, 4.0, 4.0]);
    }

    #[test]
    fn nonfinite_passthrough_skips_residual() {
        // f8: the poisoned lane passes through, its residual is untouched,
        // finite lanes still quantize.
        let g = [1.05f32, f32::INFINITY, f32::NAN];
        let mut r = [0.25f32, 0.5, 0.5];
        let mut t = [0.0f32; 3];
        ef_transmit(Wire::F8, 0, &g, Some(&mut r), &mut t);
        assert_eq!(t[0], 1.25); // 1.05 + 0.25 = 1.3 -> 1.25
        assert!(t[1].is_infinite() && t[2].is_nan());
        assert_eq!(r[1], 0.5);
        assert_eq!(r[2], 0.5);
        // 1-bit: one Inf poisons the whole chunk's scale -> raw passthrough.
        let g = [1.0f32, f32::INFINITY, -2.0];
        let mut r = [0.125f32, 0.25, 0.375];
        let mut t = [0.0f32; 3];
        ef_transmit(Wire::OneBit, 0, &g, Some(&mut r), &mut t);
        assert_eq!(t[0], 1.125); // v = g + r passes through raw
        assert!(t[1].is_infinite());
        assert_eq!(t[2], -1.625);
        assert_eq!(r, [0.125, 0.25, 0.375]); // untouched
    }

    #[test]
    fn reduce_mean_ef_f32_is_plain_kernel_and_ignores_residuals() {
        let a: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..1000).map(|i| (i as f32).cos()).collect();
        let workers = [a.as_slice(), b.as_slice()];
        let mut want = vec![0.0f32; 1000];
        reduce_mean(&workers, &mut want);
        let mut r0 = vec![0.5f32; 1000];
        let mut r1 = vec![0.5f32; 1000];
        let mut recv = vec![0.5f32; 1000];
        let mut got = vec![0.0f32; 1000];
        {
            let mut send: Vec<&mut [f32]> = vec![&mut r0, &mut r1];
            reduce_mean_ef(
                Wire::F32,
                0,
                &workers,
                Some(EfResiduals { send: &mut send, recv: &mut recv }),
                &mut got,
            );
        }
        assert_eq!(got, want);
        assert!(r0.iter().chain(r1.iter()).chain(recv.iter()).all(|&r| r == 0.5));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 400-step convergence loop: minutes under Miri
    fn reduce_mean_ef_errors_telescope() {
        // Over many steps on a constant gradient, the EF-compressed mean
        // tracks the true mean: the running average of transmitted values
        // converges even though each step is heavily quantized.
        let k = 3;
        let n = 64;
        let grads: Vec<Vec<f32>> = (0..k)
            .map(|w| (0..n).map(|i| 0.01 * ((w * n + i) as f32).sin() + 0.005).collect())
            .collect();
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut want = vec![0.0f32; n];
        reduce_mean(&refs, &mut want);
        for wire in [Wire::F8, Wire::OneBit] {
            let mut send_bufs: Vec<Vec<f32>> = vec![vec![0.0; n]; k];
            let mut recv = vec![0.0f32; n];
            let steps = 400;
            let mut avg = vec![0.0f64; n];
            for _ in 0..steps {
                let mut out = vec![0.0f32; n];
                let mut send: Vec<&mut [f32]> =
                    send_bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                reduce_mean_ef(
                    wire,
                    0,
                    &refs,
                    Some(EfResiduals { send: &mut send, recv: &mut recv }),
                    &mut out,
                );
                for (a, &o) in avg.iter_mut().zip(out.iter()) {
                    *a += o as f64 / steps as f64;
                }
            }
            for i in 0..n {
                let err = (avg[i] - want[i] as f64).abs();
                assert!(
                    err < 1e-3,
                    "{wire:?} lane {i}: averaged {} vs true {} (err {err})",
                    avg[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 2^17-value sweep per wire: minutes under Miri
    fn quantize_slice_bitwise_matches_scalar_codec() {
        let mut vals: Vec<f32> = Vec::new();
        // All 2^16 high halves (covers every exponent incl. NaN/Inf), plus
        // low-bit patterns that exercise the RNE tie cases.
        for h in 0..=u16::MAX {
            vals.push(f32::from_bits((h as u32) << 16));
            vals.push(f32::from_bits(((h as u32) << 16) | 0x8000));
            vals.push(f32::from_bits(((h as u32) << 16) | 0x18000));
            vals.push(f32::from_bits(((h as u32) << 16) | 0x7fff));
        }
        for p in [Precision::Bf16, Precision::F16] {
            let mut chunked = vals.clone();
            quantize_slice(p, &mut chunked);
            for (c, &v) in chunked.iter().zip(vals.iter()) {
                let want = p.quantize(v);
                assert_eq!(
                    c.to_bits(),
                    want.to_bits(),
                    "{p:?} diverges at input {:#010x}",
                    v.to_bits()
                );
            }
        }
    }

    #[test]
    fn payload_bytes_match_widths() {
        assert_eq!(Wire::F32.payload_bytes(1000), 4000);
        assert_eq!(Wire::Bf16.payload_bytes(1000), 2000);
        assert_eq!(Wire::F8.payload_bytes(1000), 1000);
        // 1000 elems: 125 sign bytes + 2 chunk scales.
        assert_eq!(Wire::OneBit.payload_bytes(1000), 125 + 8);
        // ~1/30 of f32 at scale.
        let n = 1 << 20;
        let ratio = Wire::F32.payload_bytes(n) as f64 / Wire::OneBit.payload_bytes(n) as f64;
        assert!(ratio > 29.0 && ratio < 32.0, "ratio {ratio}");
    }

    #[test]
    fn wire_parse_and_labels() {
        for w in Wire::ALL {
            assert_eq!(Wire::parse(w.as_str()), Some(w));
        }
        assert_eq!(Wire::parse("e4m3"), Some(Wire::F8));
        assert_eq!(Wire::parse("onebit"), Some(Wire::OneBit));
        assert_eq!(Wire::parse("2bit"), None);
        // The storage-precision parser must keep rejecting wire-only
        // spellings: f8 gradients exist on the wire, not in HBM.
        assert_eq!(Precision::parse("f8"), None);
        assert_eq!(Precision::parse("1bit"), None);
    }
}
