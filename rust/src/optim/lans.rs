//! LANS (Zheng et al. 2020, "Accelerated Large Batch Optimization of
//! BERT Pretraining in 54 minutes") — the 54-minute trajectory's
//! optimizer: LAMB's trust-ratio skeleton with two additions.
//!
//! 1. **Per-block gradient pre-normalization**: each segment's gradient
//!    is divided by its own norm before entering the moment updates, so
//!    a block whose gradient blows up (or vanishes) under a huge batch
//!    cannot distort its Adam statistics — only the *direction* feeds
//!    the moments.
//! 2. **Nesterov-style momentum**: the update blends the momentum
//!    direction `d = m_hat / (sqrt(v_hat) + eps) + wd*x` (weight
//!    `beta1`) with the look-ahead current-gradient direction
//!    `e = (g_norm / (1 - beta1^t)) / (sqrt(v_hat) + eps) + wd*x`
//!    (weight `1 - beta1`), **each with its own trust ratio** — the
//!    two-ratio construction of the paper's Algorithm 2.
//!
//! Shares the 1-based-step clamp contract of every optimizer here
//! (`step.max(1)` before the bias corrections — the PR-5 inf bug
//! class), and the `step_range` / `export_moments` / `import_moments`
//! contracts so it rides every ZeRO stage and the shard-aware
//! checkpoint path unchanged.

use super::{trust_ratio, Hyper, Optimizer, Seg};

pub struct Lans {
    pub h: Hyper,
    m: Vec<f32>,
    v: Vec<f32>,
    /// Scratch: the pre-normalized gradient of the current block.
    gq: Vec<f32>,
    /// Scratch: momentum direction `d` of the current block.
    d: Vec<f32>,
    /// Scratch: look-ahead gradient direction `e` of the current block.
    e: Vec<f32>,
}

impl Lans {
    pub fn new(n: usize, h: Hyper) -> Lans {
        Lans {
            h,
            m: vec![0.0; n],
            v: vec![0.0; n],
            gq: vec![0.0; n],
            d: vec![0.0; n],
            e: vec![0.0; n],
        }
    }

    /// Direct access to moments (checkpointing / cross-checks).
    pub fn state(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }
}

impl Optimizer for Lans {
    fn step(
        &mut self,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        step: u64,
        segs: &[Seg],
    ) -> Vec<f32> {
        let h = self.h;
        // 1-based contract: clamp so a stray step 0 cannot make the
        // bias corrections 1/(1 - beta^0) = inf (step 0 == step 1).
        let t = step.max(1) as f32;
        let (c1, c2, cg) = if h.bias_correction {
            (
                1.0 / (1.0 - h.beta1.powf(t)),
                1.0 / (1.0 - h.beta2.powf(t)),
                1.0 / (1.0 - h.beta1.powf(t)),
            )
        } else {
            (1.0, 1.0, 1.0)
        };
        let mut ratios = Vec::with_capacity(segs.len());
        for s in segs {
            let r = s.offset..s.offset + s.size;
            let x = &mut params[r.clone()];
            let g = &grads[r.clone()];
            let m = &mut self.m[r.clone()];
            let v = &mut self.v[r.clone()];
            let gq = &mut self.gq[r.clone()];
            let d = &mut self.d[r.clone()];
            let e = &mut self.e[r];
            let wd = if s.decay { h.weight_decay } else { 0.0 };
            // Per-block gradient pre-normalization: only the direction
            // enters the moments. A zero (or non-finite) block norm
            // leaves the gradient untouched — the guard mirrors
            // `trust_ratio`'s zero-norm fallback.
            let gn = h.norm.eval(g);
            let inv = if gn > 0.0 && gn.is_finite() { 1.0 / gn } else { 1.0 };
            for i in 0..x.len() {
                gq[i] = g[i] * inv;
                m[i] = h.beta1 * m[i] + (1.0 - h.beta1) * gq[i];
                v[i] = h.beta2 * v[i] + (1.0 - h.beta2) * gq[i] * gq[i];
                let denom = (c2 * v[i]).sqrt() + h.eps;
                d[i] = (c1 * m[i]) / denom + wd * x[i];
                e[i] = (cg * gq[i]) / denom + wd * x[i];
            }
            let (rd, re) = if s.adapt {
                let wn = h.norm.eval(x);
                (
                    trust_ratio(wn, h.norm.eval(d), &h),
                    trust_ratio(wn, h.norm.eval(e), &h),
                )
            } else {
                (1.0, 1.0)
            };
            let sd = lr * h.beta1 * rd;
            let se = lr * (1.0 - h.beta1) * re;
            for i in 0..x.len() {
                x[i] -= sd * d[i] + se * e[i];
            }
            // Report the momentum direction's ratio — the quantity the
            // paper's trust-ratio figures plot.
            ratios.push(rd);
        }
        ratios
    }

    fn name(&self) -> &'static str {
        "lans"
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    fn export_moments(&self, m: &mut [f32], v: &mut [f32]) {
        m.copy_from_slice(&self.m);
        v.copy_from_slice(&self.v);
    }

    fn import_moments(&mut self, m: &[f32], v: &[f32]) {
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Norm;

    /// Pre-normalization makes the moment statistics invariant to the
    /// gradient's block scale: two runs whose gradients differ by a
    /// constant factor take bitwise-identical steps (LAMB is only
    /// *approximately* scale-free through the trust ratio; LANS is
    /// exactly so, per block, by construction — modulo the division's
    /// own rounding, which a power-of-two scale keeps exact).
    #[test]
    fn gradient_scale_invariance_per_block() {
        let n = 16;
        let segs = Seg::whole(n);
        let h = Hyper::default();
        let x0: Vec<f32> = (0..n).map(|i| 0.5 + (i as f32) * 0.1).collect();
        let g: Vec<f32> =
            (0..n).map(|i| ((i as f32) - 7.5) * 0.25).collect();
        let run = |scale: f32| {
            let mut o = Lans::new(n, h);
            let mut x = x0.clone();
            for t in 1..=5 {
                let gs: Vec<f32> = g.iter().map(|v| v * scale).collect();
                o.step(&mut x, &gs, 0.01, t, &segs);
            }
            x
        };
        let a = run(1.0);
        let b = run(256.0); // power of two: g*s/||g*s|| == g/||g|| exactly
        for i in 0..n {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "i={i}");
        }
    }

    /// Zero-gradient blocks are a no-op on the moments' direction
    /// (guarded division) and keep everything finite.
    #[test]
    fn zero_gradient_block_stays_finite() {
        let mut o = Lans::new(4, Hyper::default());
        let mut x = vec![1.0f32, -1.0, 0.5, 2.0];
        for t in 1..=3 {
            o.step(&mut x, &[0.0; 4], 0.05, t, &Seg::whole(4));
        }
        assert!(x.iter().all(|v| v.is_finite()), "{x:?}");
    }

    /// The Nesterov blend differs from plain LAMB on the first step
    /// (fresh moments, where the look-ahead term dominates), and the
    /// L1/Linf norm knobs flow into the pre-normalization.
    #[test]
    fn differs_from_lamb_and_honors_norm_knob() {
        use crate::optim::Lamb;
        let h = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let g = [0.5f32, -0.3, 0.2, 0.9];
        let mut xa = vec![1.0f32, 2.0, -1.0, 0.5];
        let mut xb = xa.clone();
        Lans::new(4, h).step(&mut xa, &g, 0.1, 1, &Seg::whole(4));
        Lamb::new(4, h).step(&mut xb, &g, 0.1, 1, &Seg::whole(4));
        assert_ne!(xa, xb);
        let h1 = Hyper { norm: Norm::L1, ..h };
        let mut xc = vec![1.0f32, 2.0, -1.0, 0.5];
        Lans::new(4, h1).step(&mut xc, &g, 0.1, 1, &Seg::whole(4));
        assert_ne!(xa, xc);
    }
}
