//! LAMB (Algorithm 2) — the paper's contribution, native implementation.
//!
//! Per layer i:  m = b1*m + (1-b1)*g;  v = b2*v + (1-b2)*g^2
//!               u = m_hat / (sqrt(v_hat) + eps) + wd * x
//!               x -= lr * phi(||x||)/||u|| * u
//!
//! Matches `python/compile/kernels/lamb.py` (and therefore the AOT
//! artifact) including the adapt/decay exclusions.

use super::{trust_ratio, Hyper, Optimizer, Seg};

pub struct Lamb {
    pub h: Hyper,
    m: Vec<f32>,
    v: Vec<f32>,
    /// Scratch for the update direction (avoids per-step allocation).
    u: Vec<f32>,
}

impl Lamb {
    pub fn new(n: usize, h: Hyper) -> Lamb {
        Lamb { h, m: vec![0.0; n], v: vec![0.0; n], u: vec![0.0; n] }
    }

    /// Direct access to moments (checkpointing / artifact cross-checks).
    pub fn state(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }

    pub fn state_mut(&mut self) -> (&mut [f32], &mut [f32]) {
        (&mut self.m, &mut self.v)
    }
}

impl Optimizer for Lamb {
    fn step(
        &mut self,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        step: u64,
        segs: &[Seg],
    ) -> Vec<f32> {
        let h = self.h;
        let (c1, c2) = if h.bias_correction {
            // `step` is 1-based by contract; clamp so a stray step 0
            // cannot make c1 = 1/(1 - beta^0) = inf and poison the
            // parameters with NaN (step 0 == step 1 exactly).
            let t = step.max(1) as f32;
            (
                1.0 / (1.0 - h.beta1.powf(t)),
                1.0 / (1.0 - h.beta2.powf(t)),
            )
        } else {
            (1.0, 1.0)
        };
        let mut ratios = Vec::with_capacity(segs.len());
        for s in segs {
            let r = s.offset..s.offset + s.size;
            let x = &mut params[r.clone()];
            let g = &grads[r.clone()];
            let m = &mut self.m[r.clone()];
            let v = &mut self.v[r.clone()];
            let u = &mut self.u[r];
            let wd = if s.decay { h.weight_decay } else { 0.0 };
            for i in 0..x.len() {
                m[i] = h.beta1 * m[i] + (1.0 - h.beta1) * g[i];
                v[i] = h.beta2 * v[i] + (1.0 - h.beta2) * g[i] * g[i];
                u[i] = (c1 * m[i]) / ((c2 * v[i]).sqrt() + h.eps) + wd * x[i];
            }
            let ratio = if s.adapt {
                trust_ratio(h.norm.eval(x), h.norm.eval(u), &h)
            } else {
                1.0
            };
            let scale = lr * ratio;
            for i in 0..x.len() {
                x[i] -= scale * u[i];
            }
            ratios.push(ratio);
        }
        ratios
    }

    fn name(&self) -> &'static str {
        "lamb"
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    fn export_moments(&self, m: &mut [f32], v: &mut [f32]) {
        m.copy_from_slice(&self.m);
        v.copy_from_slice(&self.v);
    }

    fn import_moments(&mut self, m: &[f32], v: &[f32]) {
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_hand_computation_one_element() {
        // Single weight x=2, g=1, fresh state, step 1, wd=0, eps=0:
        // m=0.1, v=0.001; bias-corrected m_hat=1, v_hat=1 => u=1.
        // ratio = |x|/|u| = 2; x' = 2 - lr*2*1.
        let h = Hyper { weight_decay: 0.0, eps: 0.0, ..Hyper::default() };
        let mut o = Lamb::new(1, h);
        let mut x = vec![2.0f32];
        let r = o.step(&mut x, &[1.0], 0.1, 1, &Seg::whole(1));
        assert!((r[0] - 2.0).abs() < 1e-5, "{r:?}");
        assert!((x[0] - 1.8).abs() < 1e-5, "{x:?}");
        let (m, v) = o.state();
        assert!((m[0] - 0.1).abs() < 1e-6);
        assert!((v[0] - 0.001).abs() < 1e-7);
    }

    #[test]
    fn non_adapt_segment_pins_ratio() {
        let mut o = Lamb::new(4, Hyper::default());
        let mut x = vec![1.0, 1.0, 1.0, 1.0];
        let segs = vec![
            Seg { offset: 0, size: 2, decay: true, adapt: true },
            Seg { offset: 2, size: 2, decay: false, adapt: false },
        ];
        let r = o.step(&mut x, &[0.5; 4], 0.01, 1, &segs);
        assert_eq!(r[1], 1.0);
        assert_ne!(r[0], 1.0);
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let h = Hyper { weight_decay: 0.1, ..Hyper::default() };
        let mut o = Lamb::new(2, h);
        let mut x = vec![1.0f32, -1.0];
        for t in 1..=100 {
            o.step(&mut x, &[0.0, 0.0], 0.05, t, &Seg::whole(2));
        }
        assert!(x[0].abs() < 0.5 && x[1].abs() < 0.5, "{x:?}");
    }

    #[test]
    fn no_bias_correction_variant() {
        let h = Hyper { bias_correction: false, weight_decay: 0.0, ..Hyper::default() };
        let mut o = Lamb::new(1, h);
        let mut x = vec![1.0f32];
        // m=0.1, v=0.001 (no correction): u = 0.1/(sqrt(0.001)+eps) ~ 3.16
        o.step(&mut x, &[1.0], 0.1, 1, &Seg::whole(1));
        // ratio = 1/3.16 -> x' = 1 - 0.1*1 = 0.9 (step length = lr*||x||)
        assert!((x[0] - 0.9).abs() < 1e-4, "{x:?}");
    }
}
