//! The tuned baselines of Section 4 / Appendix H: Adam, AdamW (decoupled
//! weight decay), Adagrad, and heavy-ball momentum SGD.
//!
//! `l2_reg` folds into the gradient (the "L2 regularization" column of
//! Tables 13-25); `weight_decay` is AdamW's decoupled term.

use super::{Hyper, Optimizer, Seg};

macro_rules! adam_like {
    ($name:ident, $sname:literal, $decoupled:expr) => {
        pub struct $name {
            pub h: Hyper,
            m: Vec<f32>,
            v: Vec<f32>,
        }

        impl $name {
            pub fn new(n: usize, h: Hyper) -> Self {
                Self { h, m: vec![0.0; n], v: vec![0.0; n] }
            }

            pub fn state(&self) -> (&[f32], &[f32]) {
                (&self.m, &self.v)
            }
        }

        impl Optimizer for $name {
            fn step(
                &mut self,
                params: &mut [f32],
                grads: &[f32],
                lr: f32,
                step: u64,
                segs: &[Seg],
            ) -> Vec<f32> {
                let h = self.h;
                let (c1, c2) = if h.bias_correction {
                    // 1-based contract: clamp so step 0 cannot make
                    // c1 = 1/(1 - beta^0) = inf (step 0 == step 1).
                    let t = step.max(1) as f32;
                    (
                        1.0 / (1.0 - h.beta1.powf(t)),
                        1.0 / (1.0 - h.beta2.powf(t)),
                    )
                } else {
                    (1.0, 1.0)
                };
                for s in segs {
                    let r = s.offset..s.offset + s.size;
                    let x = &mut params[r.clone()];
                    let g = &grads[r.clone()];
                    let m = &mut self.m[r.clone()];
                    let v = &mut self.v[r];
                    let l2 = if s.decay { h.l2_reg } else { 0.0 };
                    let wd = if $decoupled && s.decay {
                        h.weight_decay
                    } else {
                        0.0
                    };
                    for i in 0..x.len() {
                        let gi = g[i] + l2 * x[i];
                        m[i] = h.beta1 * m[i] + (1.0 - h.beta1) * gi;
                        v[i] = h.beta2 * v[i] + (1.0 - h.beta2) * gi * gi;
                        let upd = (c1 * m[i]) / ((c2 * v[i]).sqrt() + h.eps);
                        x[i] -= lr * (upd + wd * x[i]);
                    }
                }
                vec![1.0; segs.len()]
            }

            fn name(&self) -> &'static str {
                $sname
            }

            fn state_bytes(&self) -> usize {
                (self.m.len() + self.v.len()) * 4
            }

            fn export_moments(&self, m: &mut [f32], v: &mut [f32]) {
                m.copy_from_slice(&self.m);
                v.copy_from_slice(&self.v);
            }

            fn import_moments(&mut self, m: &[f32], v: &[f32]) {
                self.m.copy_from_slice(m);
                self.v.copy_from_slice(v);
            }
        }
    };
}

adam_like!(Adam, "adam", false);
adam_like!(AdamW, "adamw", true);

/// Adagrad with the standard accumulating second moment.
pub struct Adagrad {
    pub h: Hyper,
    v: Vec<f32>,
}

impl Adagrad {
    pub fn new(n: usize, h: Hyper) -> Adagrad {
        Adagrad { h, v: vec![0.0; n] }
    }
}

impl Optimizer for Adagrad {
    fn step(
        &mut self,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        _step: u64,
        segs: &[Seg],
    ) -> Vec<f32> {
        let h = self.h;
        for s in segs {
            let r = s.offset..s.offset + s.size;
            let x = &mut params[r.clone()];
            let g = &grads[r.clone()];
            let v = &mut self.v[r];
            let l2 = if s.decay { h.l2_reg } else { 0.0 };
            for i in 0..x.len() {
                let gi = g[i] + l2 * x[i];
                v[i] += gi * gi;
                x[i] -= lr * gi / (v[i].sqrt() + 1e-7);
            }
        }
        vec![1.0; segs.len()]
    }

    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn state_bytes(&self) -> usize {
        self.v.len() * 4
    }

    fn export_moments(&self, m: &mut [f32], v: &mut [f32]) {
        m.fill(0.0); // no first moment
        v.copy_from_slice(&self.v);
    }

    fn import_moments(&mut self, _m: &[f32], v: &[f32]) {
        self.v.copy_from_slice(v);
    }
}

/// Heavy-ball momentum SGD — the ResNet-50 baseline of Goyal et al. 2017.
pub struct Momentum {
    pub h: Hyper,
    m: Vec<f32>,
}

impl Momentum {
    pub fn new(n: usize, h: Hyper) -> Momentum {
        Momentum { h, m: vec![0.0; n] }
    }
}

impl Optimizer for Momentum {
    fn step(
        &mut self,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        _step: u64,
        segs: &[Seg],
    ) -> Vec<f32> {
        let h = self.h;
        for s in segs {
            let r = s.offset..s.offset + s.size;
            let x = &mut params[r.clone()];
            let g = &grads[r.clone()];
            let m = &mut self.m[r];
            let l2 = if s.decay { h.l2_reg } else { 0.0 };
            for i in 0..x.len() {
                let gi = g[i] + l2 * x[i];
                m[i] = h.beta1 * m[i] + gi;
                x[i] -= lr * m[i];
            }
        }
        vec![1.0; segs.len()]
    }

    fn name(&self) -> &'static str {
        "momentum"
    }

    fn state_bytes(&self) -> usize {
        self.m.len() * 4
    }

    fn export_moments(&self, m: &mut [f32], v: &mut [f32]) {
        m.copy_from_slice(&self.m);
        v.fill(0.0); // no second moment
    }

    fn import_moments(&mut self, m: &[f32], _v: &[f32]) {
        self.m.copy_from_slice(m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_first_step_is_lr_sized() {
        // Classic Adam property: |Delta x| ~ lr on the first step.
        let h = Hyper { weight_decay: 0.0, eps: 1e-8, ..Hyper::default() };
        let mut o = Adam::new(1, h);
        let mut x = vec![1.0f32];
        o.step(&mut x, &[0.3], 0.01, 1, &Seg::whole(1));
        assert!((1.0 - x[0] - 0.01).abs() < 1e-4, "{x:?}");
    }

    #[test]
    fn adamw_decay_is_decoupled() {
        // With zero gradient, AdamW still shrinks weights; Adam does not.
        let h = Hyper { weight_decay: 0.1, ..Hyper::default() };
        let mut aw = AdamW::new(1, h);
        let mut a = Adam::new(1, h);
        let mut xw = vec![1.0f32];
        let mut xa = vec![1.0f32];
        aw.step(&mut xw, &[0.0], 0.1, 1, &Seg::whole(1));
        a.step(&mut xa, &[0.0], 0.1, 1, &Seg::whole(1));
        assert!(xw[0] < 1.0);
        assert_eq!(xa[0], 1.0);
    }

    #[test]
    fn adagrad_lr_shrinks_with_accumulation() {
        let mut o = Adagrad::new(1, Hyper::default());
        let mut x = vec![10.0f32];
        let mut deltas = Vec::new();
        for t in 1..=5 {
            let before = x[0];
            o.step(&mut x, &[1.0], 0.1, t, &Seg::whole(1));
            deltas.push(before - x[0]);
        }
        for w in deltas.windows(2) {
            assert!(w[1] < w[0], "{deltas:?}");
        }
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let h = Hyper { l2_reg: 0.0, ..Hyper::default() };
        let mut o = Momentum::new(1, h);
        let mut x = vec![0.0f32];
        o.step(&mut x, &[1.0], 1.0, 1, &Seg::whole(1));
        assert!((x[0] + 1.0).abs() < 1e-6); // m=1
        o.step(&mut x, &[1.0], 1.0, 2, &Seg::whole(1));
        assert!((x[0] + 2.9).abs() < 1e-6); // m=1.9
    }

    #[test]
    fn l2_reg_only_on_decay_segments() {
        let h = Hyper { l2_reg: 1.0, ..Hyper::default() };
        let mut o = Momentum::new(2, h);
        let mut x = vec![1.0f32, 1.0];
        let segs = vec![
            Seg { offset: 0, size: 1, decay: true, adapt: true },
            Seg { offset: 1, size: 1, decay: false, adapt: false },
        ];
        o.step(&mut x, &[0.0, 0.0], 0.1, 1, &segs);
        assert!(x[0] < 1.0);
        assert_eq!(x[1], 1.0);
    }
}
