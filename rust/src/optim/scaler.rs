//! Dynamic loss scaling — the numeric safety companion of half-width
//! gradients (`[precision] loss_scale`).
//!
//! f16's exponent floor is 2^-24: small gradient components underflow to
//! zero on the wire, silently starving the update. The classic remedy
//! (used by the mixed-precision BERT runs this repo reproduces) is to
//! multiply the loss — hence every gradient — by a large scale `S`
//! before backprop, and divide it back out just before the optimizer
//! step. [`LossScaler`] implements the *dynamic* variant:
//!
//! * **scale** ([`LossScaler::apply`]): multiply the gradient buffer by
//!   `S` (what backprop on `S * loss` would have produced);
//! * **unscale + step gate** ([`LossScaler::unscale`]): before the
//!   optimizer consumes the gradients, divide by `S` — unless any
//!   element is non-finite (the scale overflowed the half dtype's
//!   range), in which case the step is **skipped** and `S` halves
//!   (skip-and-halve);
//! * **growth**: after [`LossScaler::growth_interval`] consecutive
//!   finite steps, `S` doubles (capped), probing back toward the
//!   largest safe scale.
//!
//! `S` starts at and remains a power of two, so scaling and unscaling
//! are exact in f32 for in-range values: a scale → unscale round trip
//! is bitwise-identical for every normal float, and the f32 training
//! path with a scaler enabled stays deterministic.

/// Dynamic loss-scale state. All knobs are plain fields so configs and
/// tests can tighten them; the defaults follow the standard
/// mixed-precision recipe (init 2^16, x2 growth per 2000-step stable
/// window, halve on overflow, floor 1.0, cap 2^24).
#[derive(Clone, Copy, Debug)]
pub struct LossScaler {
    /// Current scale `S`. Kept a power of two by the default dynamics
    /// (exact unscale); a fixed-scale config simply sets it and a
    /// `growth_interval` of `u64::MAX`.
    pub scale: f32,
    /// Multiplier applied after a stable window (default 2.0).
    pub growth_factor: f32,
    /// Multiplier applied on a non-finite step (default 0.5).
    pub backoff_factor: f32,
    /// Consecutive finite steps before the scale grows (default 2000).
    pub growth_interval: u64,
    /// Lower bound for backoff (default 1.0 — never scale *down* the
    /// true gradients).
    pub min_scale: f32,
    /// Upper bound for growth (default 2^24).
    pub max_scale: f32,
    /// Finite steps since the last scale change.
    stable: u64,
    /// Steps skipped so far (observability; the paper-style logs report
    /// skipped steps alongside loss).
    pub skipped: u64,
    /// Times the scale grew.
    pub growths: u64,
}

impl LossScaler {
    /// Standard initial scale, 2^16.
    pub const DEFAULT_INIT: f32 = 65536.0;

    /// The standard dynamic recipe starting at 2^16.
    pub fn dynamic() -> LossScaler {
        LossScaler::with_scale(Self::DEFAULT_INIT)
    }

    /// Dynamic recipe with an explicit initial scale.
    pub fn with_scale(init: f32) -> LossScaler {
        assert!(
            init.is_finite() && init >= 1.0,
            "loss scale must be finite and >= 1 (got {init})"
        );
        LossScaler {
            scale: init,
            growth_factor: 2.0,
            backoff_factor: 0.5,
            growth_interval: 2000,
            min_scale: 1.0,
            max_scale: 16_777_216.0, // 2^24
            stable: 0,
            skipped: 0,
            growths: 0,
        }
    }

    /// Fixed scale: never grows, still skip-and-halves on overflow (a
    /// fixed scale that overflows every step would otherwise deadlock
    /// training).
    pub fn fixed(scale: f32) -> LossScaler {
        let mut s = LossScaler::with_scale(scale);
        s.growth_interval = u64::MAX;
        s
    }

    /// Current scale.
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Cumulative skipped (non-finite) steps — the observability
    /// counterpart of the `loss_scale.skips` trace counter.
    pub fn skips(&self) -> u64 {
        self.skipped
    }

    /// Cumulative scale growths — the observability counterpart of the
    /// `loss_scale.growths` trace counter.
    pub fn growth_count(&self) -> u64 {
        self.growths
    }

    /// Scale the gradient buffer in place — what backprop on
    /// `scale * loss` hands the reduction. Must run **before** the
    /// gradients cross a half-width wire: the whole point of the scale
    /// is that components below the wire dtype's underflow threshold
    /// (2^-24 for f16) survive quantization, and that a wire overflow
    /// is curable by halving the scale on the *next* step's pre-wire
    /// values.
    pub fn apply(&self, grads: &mut [f32]) {
        let s = self.scale;
        for g in grads.iter_mut() {
            *g *= s;
        }
    }

    /// Gate-only variant for full-precision paths with no wire to
    /// protect (the scale round-trip is exact in f32, so there is
    /// nothing to multiply in or divide out): same skip-and-halve /
    /// stable-window dynamics as [`LossScaler::unscale`], buffer
    /// untouched. Returns `false` if the step must be skipped.
    pub fn observe(&mut self, grads: &[f32]) -> bool {
        let nonfinite = grads.iter().any(|g| !g.is_finite());
        self.gate(nonfinite)
    }

    /// The single skip-and-halve / grow-on-stable-window state machine
    /// behind [`LossScaler::observe`] and [`LossScaler::unscale`] (one
    /// implementation, so the two gates cannot drift). Returns whether
    /// the step proceeds.
    fn gate(&mut self, nonfinite: bool) -> bool {
        if nonfinite {
            self.scale =
                (self.scale * self.backoff_factor).max(self.min_scale);
            self.stable = 0;
            self.skipped += 1;
            // Counter event for the host-trace/telemetry layer; inert
            // (one relaxed load) when the recorder is off, and never
            // touches the gradient buffer either way.
            crate::trace::host::counter("loss_scale.skips", 1.0);
            return false;
        }
        self.stable += 1;
        if self.stable >= self.growth_interval {
            self.scale = (self.scale * self.growth_factor).min(self.max_scale);
            self.stable = 0;
            self.growths += 1;
            crate::trace::host::counter("loss_scale.growths", 1.0);
        }
        true
    }

    /// Unscale before the optimizer step. Returns `true` and divides the
    /// buffer by the scale if every element is finite; otherwise leaves
    /// the buffer untouched, halves the scale (floored at
    /// [`LossScaler::min_scale`]), resets the stable window, and returns
    /// `false` — the caller must **skip** this optimizer step. A full
    /// stable window grows the scale for subsequent steps.
    pub fn unscale(&mut self, grads: &mut [f32]) -> bool {
        if grads.iter().any(|g| !g.is_finite()) {
            return self.gate(true);
        }
        // Divide by the scale that was applied — before the gate may
        // grow it for the next step.
        let inv = 1.0 / self.scale;
        for g in grads.iter_mut() {
            *g *= inv;
        }
        self.gate(false)
    }
}

impl Default for LossScaler {
    fn default() -> Self {
        LossScaler::dynamic()
    }
}

/// Serializable snapshot of the scaler's *dynamic* state — everything
/// the step-to-step skip-and-halve / growth machine mutates, including
/// the private stable-window counter. The configuration knobs
/// (growth/backoff factors, bounds, interval) are *not* part of the
/// snapshot: they come from the config on restore, so a resumed run can
/// retune them while continuing the saved dynamics bitwise.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScalerState {
    /// Current scale, as raw f32 bits (bitwise-exact roundtrip).
    pub scale_bits: u32,
    /// Finite steps since the last scale change.
    pub stable: u64,
    /// Cumulative skipped steps.
    pub skipped: u64,
    /// Cumulative scale growths.
    pub growths: u64,
}

impl LossScaler {
    /// Snapshot the dynamic state for checkpointing.
    pub fn export_state(&self) -> ScalerState {
        ScalerState {
            scale_bits: self.scale.to_bits(),
            stable: self.stable,
            skipped: self.skipped,
            growths: self.growths,
        }
    }

    /// Restore a [`ScalerState`] snapshot; the resumed scaler continues
    /// the dynamics bitwise where the saved run left them.
    pub fn restore_state(&mut self, s: ScalerState) {
        self.scale = f32::from_bits(s.scale_bits);
        self.stable = s.stable;
        self.skipped = s.skipped;
        self.growths = s.growths;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_unscale_roundtrip_is_bitwise_exact() {
        // Power-of-two scale: apply → unscale returns the original bits
        // for normal-range values.
        let mut s = LossScaler::dynamic();
        let orig: Vec<f32> = (0..100)
            .map(|i| ((i as f32) - 50.0) * 0.3717 + 1e-6)
            .collect();
        let mut g = orig.clone();
        s.apply(&mut g);
        assert!(s.unscale(&mut g));
        for (a, b) in g.iter().zip(&orig) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(s.skipped, 0);
    }

    #[test]
    fn non_finite_skips_and_halves_without_touching_grads() {
        let mut s = LossScaler::dynamic();
        let mut g = [1.0f32, f32::INFINITY, 3.0];
        assert!(!s.unscale(&mut g));
        assert_eq!(s.scale(), 32768.0);
        assert_eq!(s.skipped, 1);
        // buffer untouched on the skip path
        assert_eq!(g[0], 1.0);
        assert!(g[1].is_infinite());
        let mut g = [f32::NAN; 2];
        assert!(!s.unscale(&mut g));
        assert_eq!(s.scale(), 16384.0);
        assert_eq!(s.skipped, 2);
    }

    #[test]
    fn repeated_overflow_floors_at_min_scale() {
        let mut s = LossScaler::dynamic();
        let mut g = [f32::INFINITY];
        for _ in 0..60 {
            assert!(!s.unscale(&mut g));
        }
        assert_eq!(s.scale(), 1.0);
    }

    #[test]
    fn grows_after_stable_window_and_caps() {
        let mut s = LossScaler::dynamic();
        s.growth_interval = 4;
        for step in 1..=8 {
            let mut g = [0.5f32, -0.25];
            assert!(s.unscale(&mut g));
            let want = match step {
                1..=3 => 65536.0,
                4..=7 => 131072.0,
                _ => 262144.0,
            };
            assert_eq!(s.scale(), want, "step {step}");
        }
        assert_eq!(s.growths, 2);
        // a skip resets the window
        let mut g = [f32::NAN];
        assert!(!s.unscale(&mut g));
        assert_eq!(s.scale(), 131072.0);
        for _ in 0..3 {
            let mut g = [0.5f32];
            assert!(s.unscale(&mut g));
            assert_eq!(s.scale(), 131072.0);
        }
        // growth caps at max_scale
        let mut s = LossScaler::dynamic();
        s.growth_interval = 1;
        for _ in 0..100 {
            let mut g = [1.0f32];
            s.unscale(&mut g);
        }
        assert_eq!(s.scale(), s.max_scale);
    }

    #[test]
    fn fixed_scale_never_grows_but_still_backs_off() {
        let mut s = LossScaler::fixed(1024.0);
        for _ in 0..5000 {
            let mut g = [2.0f32];
            assert!(s.unscale(&mut g));
            assert_eq!(g[0], 2.0 / 1024.0);
        }
        assert_eq!(s.scale(), 1024.0);
        let mut g = [f32::INFINITY];
        assert!(!s.unscale(&mut g));
        assert_eq!(s.scale(), 512.0);
    }

    /// The gate-only variant shares the skip/grow dynamics without
    /// touching the buffer, and a scaled buffer crossing a half-width
    /// wire is exactly what survives: small components times 2^16 stay
    /// representable where the raw values would underflow to zero.
    #[test]
    fn observe_gates_without_touching_and_scale_rescues_underflow() {
        use crate::collective::Precision;
        let mut s = LossScaler::dynamic();
        s.growth_interval = 2;
        let g = [1.0f32, -0.5];
        let mut g2 = g;
        assert!(s.observe(&g2));
        assert!(s.observe(&g2));
        assert_eq!(g2, g, "observe must not modify the buffer");
        assert_eq!(s.scale(), 131072.0, "observe drives the growth window");
        assert!(!s.observe(&[f32::NAN]));
        assert_eq!(s.scale(), 65536.0);
        assert_eq!(s.skipped, 1);
        // the underflow rescue: 2^-30 quantizes to zero on an f16 wire
        // raw, but survives once scaled by 2^16
        let s = LossScaler::dynamic();
        let tiny = f32::from_bits(0x3080_0000); // 2^-30
        assert_eq!(Precision::F16.quantize(tiny), 0.0);
        let mut g = [tiny];
        s.apply(&mut g);
        assert_ne!(Precision::F16.quantize(g[0]), 0.0);
    }

    #[test]
    #[should_panic(expected = "loss scale must be finite")]
    fn rejects_bad_initial_scale() {
        LossScaler::with_scale(f32::NAN);
    }

    /// export_state → restore_state resumes the dynamics bitwise,
    /// including the private stable-window counter: the restored
    /// scaler grows on exactly the same step the uninterrupted one
    /// does.
    #[test]
    fn state_snapshot_resumes_dynamics_bitwise() {
        let mut a = LossScaler::dynamic();
        a.growth_interval = 4;
        for _ in 0..3 {
            assert!(a.unscale(&mut [1.0f32]));
        }
        let snap = a.export_state();
        assert_eq!(snap.stable, 3);
        let mut b = LossScaler::dynamic();
        b.growth_interval = 4;
        b.restore_state(snap);
        assert_eq!(b.scale().to_bits(), a.scale().to_bits());
        // one more finite step completes the window on both
        assert!(a.unscale(&mut [1.0f32]));
        assert!(b.unscale(&mut [1.0f32]));
        assert_eq!(a.scale(), 131072.0);
        assert_eq!(b.scale(), 131072.0);
        assert_eq!(b.export_state(), a.export_state());
    }

    /// Forcing a non-finite gradient through the gate bumps the
    /// cumulative getters *and* emits the trace counter events the
    /// telemetry sink aggregates.
    #[test]
    fn skip_and_growth_counters_reach_the_trace_layer() {
        use crate::trace::host;
        let _x = host::exclusive();
        host::start();
        let mut s = LossScaler::dynamic();
        s.growth_interval = 2;
        let mut g = [1.0f32, f32::NEG_INFINITY];
        assert!(!s.unscale(&mut g), "non-finite gradient must skip");
        assert_eq!(s.skips(), 1);
        assert_eq!(s.growth_count(), 0);
        for _ in 0..2 {
            let mut g = [0.25f32];
            assert!(s.unscale(&mut g));
        }
        assert_eq!(s.growth_count(), 1);
        let tr = host::drain().unwrap();
        let get = |name: &str| {
            tr.counters
                .iter()
                .find(|c| c.name == name)
                .map(|c| c.value)
        };
        assert_eq!(get("loss_scale.skips"), Some(1.0));
        assert_eq!(get("loss_scale.growths"), Some(1.0));
    }
}
