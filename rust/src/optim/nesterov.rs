//! N-LAMB and NN-LAMB (Appendix D): Nesterov momentum folded into LAMB's
//! first (and, for NN-LAMB, second) moment, following Dozat (2016)'s Nadam
//! construction with a constant beta schedule.
//!
//! Matches `ref.nlamb_update` in python/compile/kernels/ref.py.

use super::{trust_ratio, Hyper, Optimizer, Seg};

fn nesterov_step(
    h: &Hyper,
    nesterov_v: bool,
    params: &mut [f32],
    grads: &[f32],
    m_all: &mut [f32],
    v_all: &mut [f32],
    u_scratch: &mut [f32],
    lr: f32,
    step: u64,
    segs: &[Seg],
) -> Vec<f32> {
    // 1-based contract: clamp so step 0 cannot zero the cm_cur/cv_cur
    // denominators (step 0 == step 1 exactly).
    let t = step.max(1) as f32;
    let b1 = h.beta1;
    let b2 = h.beta2;
    // Nadam-style double corrections (constant-beta products -> powers).
    let cm_prev = 1.0 - b1.powf(t + 1.0);
    let cm_cur = 1.0 - b1.powf(t);
    let cv_prev = 1.0 - b2.powf(t + 1.0);
    let cv_cur = 1.0 - b2.powf(t);
    let mut ratios = Vec::with_capacity(segs.len());
    for s in segs {
        let r = s.offset..s.offset + s.size;
        let x = &mut params[r.clone()];
        let g = &grads[r.clone()];
        let m = &mut m_all[r.clone()];
        let v = &mut v_all[r.clone()];
        let u = &mut u_scratch[r];
        let wd = if s.decay { h.weight_decay } else { 0.0 };
        for i in 0..x.len() {
            m[i] = b1 * m[i] + (1.0 - b1) * g[i];
            let m_hat = b1 * m[i] / cm_prev + (1.0 - b1) * g[i] / cm_cur;
            v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
            let v_hat = if nesterov_v {
                b2 * v[i] / cv_prev + (1.0 - b2) * g[i] * g[i] / cv_cur
            } else {
                b2 * v[i] / cv_cur
            };
            u[i] = m_hat / (v_hat.sqrt() + h.eps) + wd * x[i];
        }
        let ratio = if s.adapt {
            trust_ratio(h.norm.eval(x), h.norm.eval(u), h)
        } else {
            1.0
        };
        let scale = lr * ratio;
        for i in 0..x.len() {
            x[i] -= scale * u[i];
        }
        ratios.push(ratio);
    }
    ratios
}

macro_rules! nesterov_opt {
    ($name:ident, $sname:literal, $nv:expr) => {
        pub struct $name {
            pub h: Hyper,
            m: Vec<f32>,
            v: Vec<f32>,
            u: Vec<f32>,
        }

        impl $name {
            pub fn new(n: usize, h: Hyper) -> Self {
                Self { h, m: vec![0.0; n], v: vec![0.0; n], u: vec![0.0; n] }
            }
        }

        impl Optimizer for $name {
            fn step(
                &mut self,
                params: &mut [f32],
                grads: &[f32],
                lr: f32,
                step: u64,
                segs: &[Seg],
            ) -> Vec<f32> {
                nesterov_step(
                    &self.h, $nv, params, grads, &mut self.m, &mut self.v,
                    &mut self.u, lr, step, segs,
                )
            }

            fn name(&self) -> &'static str {
                $sname
            }

            fn state_bytes(&self) -> usize {
                (self.m.len() + self.v.len()) * 4
            }

            fn export_moments(&self, m: &mut [f32], v: &mut [f32]) {
                m.copy_from_slice(&self.m);
                v.copy_from_slice(&self.v);
            }

            fn import_moments(&mut self, m: &[f32], v: &[f32]) {
                self.m.copy_from_slice(m);
                self.v.copy_from_slice(v);
            }
        }
    };
}

nesterov_opt!(NLamb, "nlamb", false);
nesterov_opt!(NnLamb, "nnlamb", true);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Lamb;

    #[test]
    fn nlamb_close_to_lamb_late_in_training() {
        // As t grows the Nesterov corrections converge toward Adam's, so
        // N-LAMB steps approach LAMB steps (Figure 1's near-identical
        // curves).
        let h = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut nl = NLamb::new(4, h);
        let mut l = Lamb::new(4, h);
        let mut xa = vec![1.0f32, 2.0, -1.0, 0.5];
        let mut xb = xa.clone();
        let segs = Seg::whole(4);
        for t in 1..=300 {
            let ga: Vec<f32> = xa.iter().map(|a| 2.0 * a).collect();
            let gb: Vec<f32> = xb.iter().map(|a| 2.0 * a).collect();
            nl.step(&mut xa, &ga, 0.01, t, &segs);
            l.step(&mut xb, &gb, 0.01, t, &segs);
        }
        for (a, b) in xa.iter().zip(&xb) {
            assert!((a - b).abs() < 0.05, "{xa:?} vs {xb:?}");
        }
    }

    #[test]
    fn nnlamb_differs_from_nlamb_early() {
        let h = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut a = NLamb::new(2, h);
        let mut b = NnLamb::new(2, h);
        let mut xa = vec![1.0f32, -2.0];
        let mut xb = xa.clone();
        a.step(&mut xa, &[0.5, 0.3], 0.1, 1, &Seg::whole(2));
        b.step(&mut xb, &[0.5, 0.3], 0.1, 1, &Seg::whole(2));
        assert_ne!(xa, xb);
    }
}
