//! LARS (Algorithm 1, You et al. 2017) — the prior layerwise method LAMB
//! is compared against throughout Section 4 / Table 2.

use super::{trust_ratio, Hyper, Optimizer, Seg};

pub struct Lars {
    pub h: Hyper,
    m: Vec<f32>,
}

impl Lars {
    pub fn new(n: usize, h: Hyper) -> Lars {
        Lars { h, m: vec![0.0; n] }
    }

    pub fn state(&self) -> &[f32] {
        &self.m
    }
}

impl Optimizer for Lars {
    fn step(
        &mut self,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        _step: u64,
        segs: &[Seg],
    ) -> Vec<f32> {
        let h = self.h;
        let mut ratios = Vec::with_capacity(segs.len());
        for s in segs {
            let r = s.offset..s.offset + s.size;
            let x = &mut params[r.clone()];
            let g = &grads[r.clone()];
            let m = &mut self.m[r];
            let wd = if s.decay { h.weight_decay } else { 0.0 };
            for i in 0..x.len() {
                m[i] = h.beta1 * m[i] + (1.0 - h.beta1) * (g[i] + wd * x[i]);
            }
            let ratio = if s.adapt {
                trust_ratio(h.norm.eval(x), h.norm.eval(m), &h)
            } else {
                1.0
            };
            let scale = lr * ratio;
            for i in 0..x.len() {
                x[i] -= scale * m[i];
            }
            ratios.push(ratio);
        }
        ratios
    }

    fn name(&self) -> &'static str {
        "lars"
    }

    fn state_bytes(&self) -> usize {
        self.m.len() * 4
    }

    fn export_moments(&self, m: &mut [f32], v: &mut [f32]) {
        m.copy_from_slice(&self.m);
        v.fill(0.0); // no second moment
    }

    fn import_moments(&mut self, m: &[f32], _v: &[f32]) {
        self.m.copy_from_slice(m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_length_is_lr_times_xnorm() {
        let h = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut o = Lars::new(2, h);
        let mut x = vec![3.0f32, 4.0]; // ||x|| = 5
        o.step(&mut x, &[1.0, 0.0], 0.1, 1, &Seg::whole(2));
        let dx = ((3.0 - x[0]).powi(2) + (4.0 - x[1]).powi(2)).sqrt();
        assert!((dx - 0.5).abs() < 1e-5, "{dx}");
    }

    #[test]
    fn momentum_smooths_direction() {
        let h = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut o = Lars::new(1, h);
        let mut x = vec![1.0f32];
        o.step(&mut x, &[1.0], 0.01, 1, &Seg::whole(1));
        // After one step, m = 0.1.
        assert!((o.state()[0] - 0.1).abs() < 1e-6);
        o.step(&mut x, &[-1.0], 0.01, 2, &Seg::whole(1));
        // m = 0.9*0.1 - 0.1 = -0.01: sign flipped only partially.
        assert!((o.state()[0] + 0.01).abs() < 1e-6);
    }
}
