//! Native Rust optimizers — every solver the paper evaluates.
//!
//! These mirror the L1 Pallas kernels / `ref.py` oracles exactly (the
//! integration test `tests/test_artifacts.rs` asserts the native LAMB step
//! matches the AOT artifact's output to f32 tolerance). They serve three
//! roles:
//!
//! 1. baselines & sweeps — the appendix tuning grids (Tables 8-25) and
//!    small-dataset studies run thousands of steps on the native trainer;
//! 2. property-test subjects for the paper's Section-3 invariants;
//! 3. a fallback step path when no `opt` artifact exists for a model.
//!
//! All operate on the flat parameter vector with the manifest's segment
//! table (`decay`/`adapt` flags follow the released LAMB implementation:
//! biases and layer-norm parameters get no weight decay and a pinned
//! trust ratio).

mod adam;
mod lamb;
mod lans;
mod lars;
mod nesterov;
mod scaler;

pub use adam::{Adagrad, Adam, AdamW, Momentum};
pub use lamb::Lamb;
pub use lans::Lans;
pub use lars::Lars;
pub use nesterov::{NLamb, NnLamb};
pub use scaler::{LossScaler, ScalerState};

use crate::manifest::ParamSeg;

/// Norm used by the trust ratio (paper Appendix F ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Norm {
    L2,
    L1,
    Linf,
}

impl Norm {
    pub fn eval(&self, x: &[f32]) -> f32 {
        match self {
            Norm::L2 => {
                x.iter().map(|&a| (a as f64) * (a as f64)).sum::<f64>().sqrt()
                    as f32
            }
            Norm::L1 => x.iter().map(|&a| a.abs() as f64).sum::<f64>() as f32,
            Norm::Linf => x.iter().fold(0.0f32, |m, &a| m.max(a.abs())),
        }
    }

    pub fn parse(s: &str) -> Option<Norm> {
        match s {
            "l2" => Some(Norm::L2),
            "l1" => Some(Norm::L1),
            "linf" => Some(Norm::Linf),
            _ => None,
        }
    }
}

/// Segment of the flat vector an optimizer treats as one "layer".
#[derive(Clone, Copy, Debug)]
pub struct Seg {
    pub offset: usize,
    pub size: usize,
    pub decay: bool,
    pub adapt: bool,
}

impl Seg {
    pub fn from_manifest(segs: &[ParamSeg]) -> Vec<Seg> {
        segs.iter()
            .map(|s| Seg {
                offset: s.offset,
                size: s.size,
                decay: s.decay,
                adapt: s.adapt,
            })
            .collect()
    }

    /// A single segment covering the whole vector (unit tests / simple
    /// convex problems).
    pub fn whole(n: usize) -> Vec<Seg> {
        vec![Seg { offset: 0, size: n, decay: true, adapt: true }]
    }
}

/// Shared hyperparameters (paper defaults from Appendix H).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Decoupled weight decay (LAMB / AdamW). Paper default 0.01.
    pub weight_decay: f32,
    /// L2 regularization folded into the gradient (Adam/Adagrad baselines).
    pub l2_reg: f32,
    /// Adam bias correction; Appendix E shows warmup subsumes it.
    pub bias_correction: bool,
    pub norm: Norm,
    /// phi clipping bounds; `None` = identity phi (released-impl default).
    pub phi_lo: Option<f32>,
    pub phi_hi: Option<f32>,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            weight_decay: 0.01,
            l2_reg: 0.0,
            bias_correction: true,
            norm: Norm::L2,
            phi_lo: None,
            phi_hi: None,
        }
    }
}

pub(crate) fn phi(w_norm: f32, h: &Hyper) -> f32 {
    let mut p = w_norm;
    if let Some(lo) = h.phi_lo {
        p = p.max(lo);
    }
    if let Some(hi) = h.phi_hi {
        p = p.min(hi);
    }
    p
}

pub(crate) fn trust_ratio(w_norm: f32, u_norm: f32, h: &Hyper) -> f32 {
    let p = phi(w_norm, h);
    if p > 0.0 && u_norm > 0.0 {
        p / u_norm
    } else {
        1.0
    }
}

/// A layerwise first-order optimizer over the flat parameter vector.
pub trait Optimizer {
    /// Apply one step in place. `step` is 1-based; implementations clamp
    /// `step.max(1)` before the bias correction, so a stray 0 cannot
    /// produce `1/(1 - beta^0) = inf` and poison the parameters (step 0
    /// and step 1 apply the identical update). Returns the per-segment
    /// trust ratios (1.0 for optimizers/segments without adaptation) —
    /// the quantity plotted in the paper's Figures 9-14.
    fn step(
        &mut self,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        step: u64,
        segs: &[Seg],
    ) -> Vec<f32>;

    /// Range-restricted step: apply the update only to segments fully
    /// contained in `[lo, hi)` of the flat vector — the ZeRO-1 shard
    /// entry point (a state owner steps just its bucket range). Returns
    /// trust ratios for the included segments, in table order.
    ///
    /// Because every optimizer here is strictly per-segment, stepping a
    /// partition of `[0, n)` range by range is f32-exactly equal to one
    /// dense `step` (asserted in `tests/test_exec.rs`).
    fn step_range(
        &mut self,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        step: u64,
        segs: &[Seg],
        lo: usize,
        hi: usize,
    ) -> Vec<f32> {
        let sub: Vec<Seg> = segs
            .iter()
            .filter(|s| s.offset >= lo && s.offset + s.size <= hi)
            .copied()
            .collect();
        self.step(params, grads, lr, step, &sub)
    }

    fn name(&self) -> &'static str;

    /// Moment buffer size (for state-size accounting in the pod model).
    fn state_bytes(&self) -> usize;

    /// Copy the moment state into `(m, v)` for checkpointing. Both
    /// buffers are fully overwritten — zeroed wherever this optimizer
    /// keeps no such buffer (momentum-style solvers have no second
    /// moment; a zero moment is exactly a fresh one, so the
    /// export/import pair round-trips every optimizer losslessly). The
    /// dense half of the shard-aware checkpoint path
    /// (`exec::Zero1State::checkpoint` and friends).
    fn export_moments(&self, m: &mut [f32], v: &mut [f32]) {
        m.fill(0.0);
        v.fill(0.0);
    }

    /// Restore moment state captured by [`Optimizer::export_moments`];
    /// buffers this optimizer does not keep are ignored.
    fn import_moments(&mut self, _m: &[f32], _v: &[f32]) {}
}

/// Construct an optimizer by paper name.
pub fn build(name: &str, n: usize, h: Hyper) -> Option<Box<dyn Optimizer>> {
    Some(match name {
        "lamb" => Box::new(Lamb::new(n, h)),
        "lars" => Box::new(Lars::new(n, h)),
        "adam" => Box::new(Adam::new(n, h)),
        "adamw" => Box::new(AdamW::new(n, h)),
        "adagrad" => Box::new(Adagrad::new(n, h)),
        "momentum" => Box::new(Momentum::new(n, h)),
        "nlamb" => Box::new(NLamb::new(n, h)),
        "nnlamb" => Box::new(NnLamb::new(n, h)),
        "lans" => Box::new(Lans::new(n, h)),
        _ => return None,
    })
}

pub const ALL: &[&str] = &[
    "lamb", "lars", "adam", "adamw", "adagrad", "momentum", "nlamb",
    "nnlamb", "lans",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert!((Norm::L2.eval(&x) - 5.0).abs() < 1e-6);
        assert!((Norm::L1.eval(&x) - 7.0).abs() < 1e-6);
        assert!((Norm::Linf.eval(&x) - 4.0).abs() < 1e-6);
        assert_eq!(Norm::parse("l1"), Some(Norm::L1));
        assert_eq!(Norm::parse("lp"), None);
    }

    #[test]
    fn trust_ratio_guards() {
        let h = Hyper::default();
        assert_eq!(trust_ratio(0.0, 1.0, &h), 1.0);
        assert_eq!(trust_ratio(1.0, 0.0, &h), 1.0);
        assert!((trust_ratio(2.0, 4.0, &h) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn phi_clipping() {
        let h = Hyper { phi_lo: Some(0.5), phi_hi: Some(2.0), ..Hyper::default() };
        assert_eq!(phi(0.1, &h), 0.5);
        assert_eq!(phi(1.0, &h), 1.0);
        assert_eq!(phi(5.0, &h), 2.0);
    }

    #[test]
    fn build_all() {
        for name in ALL {
            let o = build(name, 16, Hyper::default()).unwrap();
            assert_eq!(&o.name(), name);
        }
        assert!(build("sgd2", 16, Hyper::default()).is_none());
    }

    /// Every optimizer reduces a simple separable quadratic.
    #[test]
    fn all_reduce_quadratic() {
        let n = 32;
        let segs = Seg::whole(n);
        for name in ALL {
            let mut opt = build(
                name,
                n,
                Hyper { weight_decay: 0.0, l2_reg: 0.0, ..Hyper::default() },
            )
            .unwrap();
            let mut x: Vec<f32> =
                (0..n).map(|i| 1.0 + (i as f32) * 0.1).collect();
            let f = |x: &[f32]| -> f32 { x.iter().map(|a| a * a).sum() };
            let f0 = f(&x);
            // Adagrad's effective LR decays as 1/sqrt(sum g^2); give it a
            // proportionally larger base LR, as the paper's grids do.
            let lr = match *name {
                "adagrad" => 0.3,
                "momentum" => 0.02,
                _ => 0.01,
            };
            for t in 1..=200 {
                let g: Vec<f32> = x.iter().map(|a| 2.0 * a).collect();
                opt.step(&mut x, &g, lr, t, &segs);
            }
            let f1 = f(&x);
            assert!(f1 < 0.5 * f0, "{name}: {f0} -> {f1}");
            assert!(x.iter().all(|a| a.is_finite()), "{name} diverged");
        }
    }

    /// Regression (ISSUE 5): the 1-based step contract is enforced by
    /// clamping — step 0 and step 1 apply bitwise-identical, finite
    /// updates for every optimizer (before the clamp, step 0 made the
    /// bias correction 1/(1 - beta^0) = inf in LAMB/Adam/N-LAMB and
    /// silently poisoned the parameters with NaN).
    #[test]
    fn step_zero_equals_step_one_and_stays_finite() {
        let n = 24;
        let segs = Seg::whole(n);
        let x0: Vec<f32> = (0..n).map(|i| 0.5 + (i as f32) * 0.25).collect();
        let g: Vec<f32> =
            (0..n).map(|i| ((i as f32) - 11.5) * 0.125).collect();
        for name in ALL {
            let run = |step: u64| {
                let mut opt = build(name, n, Hyper::default()).unwrap();
                let mut x = x0.clone();
                let ratios = opt.step(&mut x, &g, 0.01, step, &segs);
                (x, ratios)
            };
            let (x_zero, r_zero) = run(0);
            let (x_one, r_one) = run(1);
            assert!(
                x_zero.iter().all(|v| v.is_finite()),
                "{name}: step 0 produced non-finite params: {x_zero:?}"
            );
            assert!(r_zero.iter().all(|v| v.is_finite()), "{name}");
            for i in 0..n {
                assert_eq!(
                    x_zero[i].to_bits(),
                    x_one[i].to_bits(),
                    "{name}: step 0 vs step 1 diverge at param {i}"
                );
            }
            assert_eq!(r_zero, r_one, "{name}: trust ratios");
            // step 0 must also leave usable state: continuing at step 2
            // stays finite
            let mut opt = build(name, n, Hyper::default()).unwrap();
            let mut x = x0.clone();
            opt.step(&mut x, &g, 0.01, 0, &segs);
            opt.step(&mut x, &g, 0.01, 2, &segs);
            assert!(x.iter().all(|v| v.is_finite()), "{name} step 0 -> 2");
        }
    }

    /// export_moments / import_moments round-trips every optimizer: a
    /// fresh instance fed the exported state continues bitwise-identical
    /// to the uninterrupted original (the dense half of the shard-aware
    /// checkpoint contract).
    #[test]
    fn moment_export_import_roundtrips_every_optimizer() {
        let n = 40;
        let segs = Seg::whole(n);
        for name in ALL {
            let h = Hyper::default();
            let mut orig = build(name, n, h).unwrap();
            let mut x: Vec<f32> =
                (0..n).map(|i| 1.0 + (i as f32) * 0.1).collect();
            let grad = |t: u64| -> Vec<f32> {
                (0..n)
                    .map(|i| (((i as u64 + 3 * t) % 7) as f32) * 0.1 - 0.3)
                    .collect()
            };
            for t in 1..=3 {
                orig.step(&mut x, &grad(t), 0.01, t, &segs);
            }
            // checkpoint: params + exported moments
            let mut m = vec![0.0f32; n];
            let mut v = vec![0.0f32; n];
            orig.export_moments(&mut m, &mut v);
            let mut restored = build(name, n, h).unwrap();
            restored.import_moments(&m, &v);
            let mut xr = x.clone();
            for t in 4..=6 {
                let g = grad(t);
                let ra = orig.step(&mut x, &g, 0.01, t, &segs);
                let rb = restored.step(&mut xr, &g, 0.01, t, &segs);
                assert_eq!(ra, rb, "{name} ratios step {t}");
                for i in 0..n {
                    assert_eq!(
                        x[i].to_bits(),
                        xr[i].to_bits(),
                        "{name} param {i} step {t}"
                    );
                }
            }
        }
    }

    /// Stepping a partition of the vector range by range must equal one
    /// dense step exactly, for every optimizer (the ZeRO-1 shard
    /// contract).
    #[test]
    fn step_range_partition_equals_dense() {
        let sizes = [10usize, 6, 20, 4, 24];
        let n: usize = sizes.iter().sum();
        let mut segs = Vec::new();
        let mut off = 0;
        for (i, &s) in sizes.iter().enumerate() {
            segs.push(Seg {
                offset: off,
                size: s,
                decay: i % 2 == 0,
                adapt: i != 3,
            });
            off += s;
        }
        let cut = 36; // boundary after segment 2
        for name in ALL {
            let h = Hyper::default();
            let mut dense = build(name, n, h).unwrap();
            let mut parted = build(name, n, h).unwrap();
            let mut xa: Vec<f32> =
                (0..n).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
            let mut xb = xa.clone();
            for t in 1..=3 {
                let g: Vec<f32> =
                    (0..n).map(|i| ((i * 5 % 11) as f32) * 0.1 - 0.5).collect();
                let ra = dense.step(&mut xa, &g, 0.01, t, &segs);
                let mut rb =
                    parted.step_range(&mut xb, &g, 0.01, t, &segs, 0, cut);
                rb.extend(parted.step_range(&mut xb, &g, 0.01, t, &segs, cut, n));
                assert_eq!(ra, rb, "{name} ratios step {t}");
                assert_eq!(xa, xb, "{name} params step {t}");
            }
        }
    }

    /// Section-3 invariant: the LAMB step length per layer is
    /// lr * phi(||x||), independent of gradient scale.
    #[test]
    fn lamb_step_norm_invariant() {
        let n = 64;
        let segs = Seg::whole(n);
        let h = Hyper { weight_decay: 0.0, eps: 0.0, ..Hyper::default() };
        for scale in [1.0f32, 1e3, 1e-3] {
            let mut opt = Lamb::new(n, h);
            let x0: Vec<f32> =
                (0..n).map(|i| ((i * 37 % 11) as f32) - 5.0).collect();
            let mut x = x0.clone();
            // strictly nonzero gradient: with eps = 0 a zero coordinate
            // would give 0/0 (the kernels share this contract; eps > 0 in
            // any real configuration)
            let g: Vec<f32> = (0..n)
                .map(|i| scale * (((i * 13 % 7) as f32) - 3.5))
                .collect();
            opt.step(&mut x, &g, 0.1, 1, &segs);
            let delta: f32 = Norm::L2.eval(
                &x.iter().zip(&x0).map(|(a, b)| a - b).collect::<Vec<_>>(),
            );
            let expect = 0.1 * Norm::L2.eval(&x0);
            assert!(
                (delta - expect).abs() / expect < 1e-3,
                "scale {scale}: {delta} vs {expect}"
            );
        }
    }
}
