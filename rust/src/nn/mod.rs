//! Native tiny neural network (MLP + softmax cross-entropy, manual
//! backprop) over the flat-parameter / segment-table convention shared
//! with the BERT artifacts.
//!
//! This is the fast substrate for the paper's appendix-scale studies: the
//! ImageNet/CIFAR/MNIST-proxy optimizer comparisons (Tables 3, 5, 6, 7;
//! Figures 1-5) and the tuning grids (Tables 8-25) each need thousands of
//! full training runs — far too many for the PJRT BERT path, and exactly
//! what a few-thousand-parameter MLP trained in milliseconds covers while
//! preserving what those experiments measure: relative optimizer behaviour
//! under layerwise scale disparity (the anisotropic input noise in
//! `data::image` supplies the disparity).

use crate::optim::Seg;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct MlpConfig {
    pub input: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
}

impl MlpConfig {
    /// LeNet-proxy (Table 7 / MNIST scale).
    pub fn lenet_proxy(input: usize, classes: usize) -> MlpConfig {
        MlpConfig { input, hidden: vec![64, 32], classes }
    }

    /// DavidNet/ResNet-proxy (Tables 3/5/6 scale) — deeper and wider so
    /// layerwise scale structure matters more.
    pub fn resnet_proxy(input: usize, classes: usize) -> MlpConfig {
        MlpConfig { input, hidden: vec![128, 128, 64], classes }
    }
}

/// Fully-connected net: relu hidden layers, linear head, softmax-CE loss.
pub struct Mlp {
    pub cfg: MlpConfig,
    pub params: Vec<f32>,
    segs: Vec<Seg>,
    dims: Vec<(usize, usize)>, // (in, out) per layer
}

impl Mlp {
    pub fn new(cfg: MlpConfig, seed: u64) -> Mlp {
        let mut dims = Vec::new();
        let mut prev = cfg.input;
        for &h in &cfg.hidden {
            dims.push((prev, h));
            prev = h;
        }
        dims.push((prev, cfg.classes));

        let mut segs = Vec::new();
        let mut off = 0;
        for &(i, o) in &dims {
            segs.push(Seg { offset: off, size: i * o, decay: true, adapt: true });
            off += i * o;
            segs.push(Seg { offset: off, size: o, decay: false, adapt: false });
            off += o;
        }
        let mut rng = Rng::new(seed ^ 0x3153_7370);
        let mut params = vec![0.0f32; off];
        for (li, &(i, _o)) in dims.iter().enumerate() {
            let w = &segs[2 * li];
            let std = (2.0 / i as f64).sqrt() as f32; // He init
            for p in &mut params[w.offset..w.offset + w.size] {
                *p = rng.normal_f32(std);
            }
        }
        Mlp { cfg, params, segs, dims }
    }

    pub fn segs(&self) -> &[Seg] {
        &self.segs
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Forward + backward over a batch. `x`: `[n, input]` row-major,
    /// `y`: `[n]` class ids. Writes dL/dparams into `grads` (overwritten).
    /// Returns (mean loss, accuracy).
    pub fn loss_grad(
        &self,
        x: &[f32],
        y: &[u32],
        grads: &mut [f32],
    ) -> (f32, f32) {
        self.run(x, y, Some(grads), None)
    }

    /// As [`loss_grad`](Self::loss_grad), additionally invoking
    /// `retired(seg_lo, grads)` as backprop retires each layer's weight
    /// and bias gradients — after the call, every segment with index
    /// `>= seg_lo` is final. Backprop walks layers last-to-first, so the
    /// retired suffix grows downward: exactly the readiness order the
    /// exec engine's bucketed all-reduce overlaps against.
    pub fn loss_grad_retiring(
        &self,
        x: &[f32],
        y: &[u32],
        grads: &mut [f32],
        retired: &mut dyn FnMut(usize, &[f32]),
    ) -> (f32, f32) {
        self.run(x, y, Some(grads), Some(retired))
    }

    /// Forward only.
    pub fn evaluate(&self, x: &[f32], y: &[u32]) -> (f32, f32) {
        self.run(x, y, None, None)
    }

    fn run(
        &self,
        x: &[f32],
        y: &[u32],
        grads: Option<&mut [f32]>,
        mut retired: Option<&mut dyn FnMut(usize, &[f32])>,
    ) -> (f32, f32) {
        let n = y.len();
        assert_eq!(x.len(), n * self.cfg.input);
        let nl = self.dims.len();

        // Forward, keeping activations per layer.
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nl + 1);
        acts.push(x.to_vec());
        for (li, &(di, dout)) in self.dims.iter().enumerate() {
            let w = &self.params[self.segs[2 * li].offset..];
            let b = &self.params[self.segs[2 * li + 1].offset..];
            let inp = &acts[li];
            let mut out = vec![0.0f32; n * dout];
            for s in 0..n {
                let xi = &inp[s * di..(s + 1) * di];
                let oi = &mut out[s * dout..(s + 1) * dout];
                oi.copy_from_slice(&b[..dout]);
                for i in 0..di {
                    let xv = xi[i];
                    if xv != 0.0 {
                        let wr = &w[i * dout..(i + 1) * dout];
                        for o in 0..dout {
                            oi[o] += xv * wr[o];
                        }
                    }
                }
                if li + 1 < nl {
                    for v in oi.iter_mut() {
                        *v = v.max(0.0); // relu
                    }
                }
            }
            acts.push(out);
        }

        // Softmax CE + accuracy on the logits.
        let c = self.cfg.classes;
        let logits = acts.last().unwrap();
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let mut dlogits = vec![0.0f32; n * c];
        for s in 0..n {
            let l = &logits[s * c..(s + 1) * c];
            let mx = l.iter().cloned().fold(f32::MIN, f32::max);
            let mut z = 0.0f64;
            for &v in l {
                z += ((v - mx) as f64).exp();
            }
            let target = y[s] as usize;
            loss += (z.ln() + mx as f64) - l[target] as f64;
            // total_cmp: NaN-safe — a diverged run must reach the
            // divergence detector, not panic here.
            let argmax = l
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            if argmax == target {
                correct += 1;
            }
            let d = &mut dlogits[s * c..(s + 1) * c];
            for o in 0..c {
                let p = (((l[o] - mx) as f64).exp() / z) as f32;
                d[o] = (p - if o == target { 1.0 } else { 0.0 }) / n as f32;
            }
        }
        let loss = (loss / n as f64) as f32;
        let acc = correct as f32 / n as f32;

        let grads = match grads {
            Some(g) => g,
            None => return (loss, acc),
        };
        assert_eq!(grads.len(), self.params.len());
        grads.fill(0.0);

        // Backward.
        let mut delta = dlogits;
        for li in (0..nl).rev() {
            let (di, dout) = self.dims[li];
            let wseg = self.segs[2 * li];
            let bseg = self.segs[2 * li + 1];
            let w = &self.params[wseg.offset..wseg.offset + wseg.size];
            let inp = &acts[li];
            // dW, db
            {
                let (gw, gb) = {
                    let (a, b) = grads.split_at_mut(bseg.offset);
                    (&mut a[wseg.offset..], &mut b[..dout])
                };
                for s in 0..n {
                    let xi = &inp[s * di..(s + 1) * di];
                    let dsl = &delta[s * dout..(s + 1) * dout];
                    for o in 0..dout {
                        gb[o] += dsl[o];
                    }
                    for i in 0..di {
                        let xv = xi[i];
                        if xv != 0.0 {
                            let gr = &mut gw[i * dout..(i + 1) * dout];
                            for o in 0..dout {
                                gr[o] += xv * dsl[o];
                            }
                        }
                    }
                }
            }
            if let Some(h) = retired.as_mut() {
                h(2 * li, grads);
            }
            if li == 0 {
                break;
            }
            // delta_prev = (delta @ W^T) * relu'(act_prev)
            let mut prev = vec![0.0f32; n * di];
            for s in 0..n {
                let dsl = &delta[s * dout..(s + 1) * dout];
                let ai = &acts[li][s * di..(s + 1) * di];
                let pd = &mut prev[s * di..(s + 1) * di];
                for i in 0..di {
                    if ai[i] > 0.0 {
                        let wr = &w[i * dout..(i + 1) * dout];
                        let mut acc = 0.0f32;
                        for o in 0..dout {
                            acc += wr[o] * dsl[o];
                        }
                        pd[i] = acc;
                    }
                }
            }
            delta = prev;
        }
        (loss, acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::image::ImageTask;
    use crate::optim::{build, Hyper};

    #[test]
    fn segment_layout_contiguous() {
        let m = Mlp::new(MlpConfig::lenet_proxy(16, 4), 0);
        let mut off = 0;
        for s in m.segs() {
            assert_eq!(s.offset, off);
            off += s.size;
        }
        assert_eq!(off, m.n_params());
    }

    #[test]
    fn loss_near_uniform_at_init() {
        let m = Mlp::new(MlpConfig::lenet_proxy(8, 10), 1);
        let t = ImageTask::new(8, 10, 2);
        let mut rng = Rng::new(3);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        t.sample(&mut rng, 64, &mut x, &mut y);
        let (loss, acc) = m.evaluate(&x, &y);
        assert!((loss - (10.0f32).ln()).abs() < 1.0, "loss {loss}");
        assert!(acc < 0.4);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let m = Mlp::new(MlpConfig { input: 5, hidden: vec![7], classes: 3 }, 4);
        let t = ImageTask::new(5, 3, 5);
        let mut rng = Rng::new(6);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        t.sample(&mut rng, 8, &mut x, &mut y);
        let mut g = vec![0.0f32; m.n_params()];
        let (l0, _) = m.loss_grad(&x, &y, &mut g);
        assert!(l0.is_finite());
        // Check a scatter of coordinates with central differences.
        let mut m2 = Mlp::new(MlpConfig { input: 5, hidden: vec![7], classes: 3 }, 4);
        let eps = 1e-3f32;
        for &idx in &[0usize, 3, 17, 35, 40, m.n_params() - 1] {
            let orig = m2.params[idx];
            m2.params[idx] = orig + eps;
            let (lp, _) = m2.evaluate(&x, &y);
            m2.params[idx] = orig - eps;
            let (lm, _) = m2.evaluate(&x, &y);
            m2.params[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs an {}",
                g[idx]
            );
        }
    }

    #[test]
    fn retiring_backward_matches_and_orders() {
        let m = Mlp::new(MlpConfig { input: 6, hidden: vec![8, 5], classes: 3 }, 11);
        let t = ImageTask::new(6, 3, 12);
        let mut rng = Rng::new(13);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        t.sample(&mut rng, 16, &mut x, &mut y);
        let mut ga = vec![0.0f32; m.n_params()];
        let (la, _) = m.loss_grad(&x, &y, &mut ga);
        let mut gb = vec![0.0f32; m.n_params()];
        let mut seen: Vec<usize> = Vec::new();
        let segs = m.segs().to_vec();
        let (lb, _) = m.loss_grad_retiring(&x, &y, &mut gb, &mut |j, g| {
            // the retired suffix must already hold its final values
            let lo = segs[j].offset;
            assert!(g[lo..].iter().zip(&ga[lo..]).all(|(a, b)| a == b));
            seen.push(j);
        });
        assert_eq!(la, lb);
        assert_eq!(ga, gb);
        // one callback per layer, last layer first, down to segment 0
        let nl = 3; // 2 hidden + head
        let want: Vec<usize> = (0..nl).rev().map(|li| 2 * li).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn trains_to_high_accuracy() {
        let task = ImageTask::new(16, 4, 7);
        let mut m = Mlp::new(MlpConfig::lenet_proxy(16, 4), 8);
        let segs = m.segs().to_vec();
        let mut opt = build("lamb", m.n_params(), Hyper::default()).unwrap();
        let mut rng = Rng::new(9);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        let mut g = vec![0.0f32; m.n_params()];
        for t in 1..=300 {
            task.sample(&mut rng, 64, &mut x, &mut y);
            m.loss_grad(&x, &y, &mut g);
            opt.step(&mut m.params, &g, 0.02, t, &segs);
        }
        task.sample(&mut rng, 512, &mut x, &mut y);
        let (_, acc) = m.evaluate(&x, &y);
        assert!(acc > 0.8, "acc {acc}");
    }
}
