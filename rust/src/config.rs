//! Configuration system: a TOML-subset parser (offline build — no `toml`
//! crate) plus the typed `TrainConfig` the CLI and coordinator consume.
//!
//! Supported TOML subset: `[section]` / `[a.b]` headers, `key = value`
//! with string / integer / float / boolean / flat-array values, `#`
//! comments. That covers every config under `configs/`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

// ---------------------------------------------------------------------
// TOML-subset parsing
// ---------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat `section.key -> value` map.
pub type TomlDoc = BTreeMap<String, TomlValue>;

fn parse_value(s: &str) -> Result<TomlValue> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let mut arr = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for item in inner.split(',') {
                arr.push(parse_value(item)?);
            }
        }
        return Ok(TomlValue::Arr(arr));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse TOML value: {s:?}")
}

/// Parse the TOML subset into a flat dotted-key map.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    for (lno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            // only strip comments outside strings (strings in our configs
            // never contain '#')
            Some(i) if !raw[..i].contains('"') || raw[..i].matches('"').count() % 2 == 0 => &raw[..i],
            _ => raw,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        doc.insert(
            key,
            parse_value(v).with_context(|| format!("line {}", lno + 1))?,
        );
    }
    Ok(doc)
}

// ---------------------------------------------------------------------
// Typed training configuration
// ---------------------------------------------------------------------

/// Interconnect topology + collective-schedule knobs (config section
/// `[topology]`). Link parameters default to the pod model's calibrated
/// flat ring (44 us/phase, 70 GB/s), so an absent table — or one that
/// only sets `schedule` — reprices nothing: `schedule = "ring"` on the
/// default topology is bitwise-identical to the pre-topology model.
///
/// ```toml
/// [topology]
/// node_size = 8          # chips per node (1 = flat)
/// intra_gbps = 600.0     # intra-node link bandwidth, GB/s
/// inter_gbps = 70.0      # inter-node link bandwidth, GB/s
/// intra_us = 1.0         # intra-node per-phase latency, us
/// inter_us = 44.0        # inter-node per-phase latency, us
/// schedule = "auto"      # auto | ring | hierarchical | tree
/// cross_step = true      # pipeline ZeRO-2's param gather into the
///                        # next step's forward pass
/// ```
///
/// Mistyped values hard-error like `exec.zero_stage` (a string where a
/// number belongs, a float `node_size`, an unknown `schedule` name)
/// instead of silently pricing the wrong machine.
#[derive(Clone, Copy, Debug)]
pub struct TopologyConfig {
    /// Chips per node; 1 = flat topology.
    pub node_size: usize,
    /// Intra-node link bandwidth in GB/s (None = pod default).
    pub intra_gbps: Option<f64>,
    /// Inter-node link bandwidth in GB/s (None = pod default).
    pub inter_gbps: Option<f64>,
    /// Intra-node per-phase latency in microseconds (None = pod default).
    pub intra_us: Option<f64>,
    /// Inter-node per-phase latency in microseconds (None = pod default).
    pub inter_us: Option<f64>,
    /// Schedule selection: `auto` or a fixed kind.
    pub policy: crate::collective::SchedulePolicy,
    /// Overlap ZeRO-2's trailing parameter all-gather with the next
    /// step's forward pass (steady-state pipelining).
    pub cross_step: bool,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        TopologyConfig {
            node_size: 1,
            intra_gbps: None,
            inter_gbps: None,
            intra_us: None,
            inter_us: None,
            policy: crate::collective::SchedulePolicy::default(),
            cross_step: false,
        }
    }
}

impl TopologyConfig {
    /// Materialize into a `collective::Topology`, inheriting any unset
    /// link parameter from `base` (the pod's calibrated ring) *as-is* —
    /// no unit round-trip, so the default table reproduces the flat
    /// model bit-for-bit.
    pub fn build(&self, base: crate::collective::RingCost) -> crate::collective::Topology {
        use crate::collective::{RingCost, Topology};
        let link = |us: Option<f64>, gbps: Option<f64>| RingCost {
            alpha: us.map_or(base.alpha, |u| u * 1e-6),
            beta: gbps.map_or(base.beta, |g| g * 1e9),
        };
        Topology {
            node_size: self.node_size.max(1),
            intra: link(self.intra_us, self.intra_gbps),
            inter: link(self.inter_us, self.inter_gbps),
            policy: self.policy,
            cross_step: self.cross_step,
        }
    }
}

/// Storage/wire precision + loss scaling (config section `[precision]`).
///
/// ```toml
/// [precision]
/// params = "bf16"        # f32 | bf16 | f16 — storage + wire dtype
/// grads  = "bf16"        # f32 | bf16 | f16 — gradient storage dtype
/// grads_wire = "1bit"    # f32 | bf16 | f16 | f8 | 1bit — gradient wire
///                        # format; default: the grads storage dtype.
///                        # f8/1bit are error-feedback compressed.
/// master_weights = true  # default: forced on when params are half
/// loss_scale = "dynamic" # "none" | "dynamic" | a fixed scale >= 1
/// norms_fp32 = true      # keep layer norms / biases in fp32 storage
///                        # even when params are half (default false)
/// ```
///
/// Mistyped values hard-error like `exec.zero_stage` (a number where a
/// dtype string belongs, an unknown dtype name, a boolean loss scale)
/// instead of silently training the wrong numerics. Half-width params
/// additionally require `zero_stage >= 2`: the fp32 master-weight step
/// path lives in the ZeRO-2/3 sharded states.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionConfig {
    /// Parameter storage + wire dtype.
    pub params: crate::collective::Precision,
    /// Gradient storage + wire dtype.
    pub grads: crate::collective::Precision,
    /// Gradient wire-format override; `None` derives the wire from the
    /// gradient storage dtype. `f8`/`1bit` turn on error-feedback
    /// compressed collectives.
    pub grads_wire: Option<crate::collective::Wire>,
    /// fp32 master-weight copy; `None` = auto (on iff params are
    /// half-width). Explicitly disabling it with half params is a
    /// config error.
    pub master_weights: Option<bool>,
    /// Gradient loss scaling (`optim::LossScaler`).
    pub loss_scale: LossScaleConfig,
    /// Per-segment override: keep no-decay segments (layer norms,
    /// biases — the LM-head bias included) in fp32 storage even when
    /// `params` is half-width. Their resident copy is never quantized,
    /// so the norm statistics step at full precision.
    pub norms_fp32: bool,
}

/// `[precision] loss_scale` spellings.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LossScaleConfig {
    /// No scaling (the f32 default).
    None,
    /// Dynamic: start at 2^16, skip-and-halve on non-finite, double
    /// after a stable window.
    Dynamic,
    /// Fixed scale (still skip-and-halves on overflow so training can
    /// recover).
    Fixed(f32),
}

impl Default for PrecisionConfig {
    fn default() -> Self {
        PrecisionConfig {
            params: crate::collective::Precision::F32,
            grads: crate::collective::Precision::F32,
            grads_wire: None,
            master_weights: None,
            loss_scale: LossScaleConfig::None,
            norms_fp32: false,
        }
    }
}

impl PrecisionConfig {
    /// Resolve into the plan the numeric/accounting layers consume.
    pub fn plan(&self) -> crate::collective::PrecisionPlan {
        crate::collective::PrecisionPlan {
            params: self.params,
            grads: self.grads,
            master_weights: self.master_weights.unwrap_or(
                self.params != crate::collective::Precision::F32,
            ),
            grads_wire: self.grads_wire,
            norms_fp32: self.norms_fp32,
        }
    }

    /// Build the configured loss scaler, if any.
    pub fn scaler(&self) -> Option<crate::optim::LossScaler> {
        match self.loss_scale {
            LossScaleConfig::None => None,
            LossScaleConfig::Dynamic => {
                Some(crate::optim::LossScaler::dynamic())
            }
            LossScaleConfig::Fixed(s) => {
                Some(crate::optim::LossScaler::fixed(s))
            }
        }
    }
}

/// Structured tracing + telemetry (config section `[trace]`).
///
/// ```toml
/// [trace]
/// enabled = true             # master switch (default false)
/// dir = "results/trace"      # output directory
/// sim_trace = true           # write the simulated-time Perfetto trace
/// host_trace = true          # record host-time spans (exec engine)
/// metrics_jsonl = true       # write the JSONL telemetry sink
/// ```
///
/// Mistyped values hard-error like `[exec]`/`[topology]` (a string
/// where a boolean belongs, a number `dir`) instead of silently
/// dropping the telemetry someone asked for. Tracing never changes
/// numerics: hooks read clocks and metadata only, so a traced run is
/// bitwise-identical to an untraced one.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceConfig {
    /// Master switch; the sub-switches below are ignored when false.
    pub enabled: bool,
    /// Output directory for trace + telemetry files.
    pub dir: String,
    /// Write the simulated-time Perfetto trace (`trace::sim`) per stage.
    pub sim_trace: bool,
    /// Record host-time spans through `trace::host`.
    pub host_trace: bool,
    /// Write the `MetricsSink` JSONL (`trace::sink`).
    pub metrics_jsonl: bool,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            enabled: false,
            dir: "results/trace".into(),
            sim_trace: true,
            host_trace: true,
            metrics_jsonl: true,
        }
    }
}

/// 3D-parallel mesh axes (config section `[mesh]`): how the pod's
/// chips factor into data-parallel replicas x tensor-parallel shards x
/// pipeline stages (`cluster::Mesh`). The default mesh is pure data
/// parallelism — tp = pp = 1 — which prices bitwise-identically to the
/// pre-mesh model at every ZeRO stage.
///
/// ```toml
/// [mesh]
/// dp = 128                    # data-parallel replicas; omit for
///                             # auto = chips / (tp * pp)
/// tp = 4                      # tensor-parallel shards per matmul
/// pp = 2                      # pipeline stages (1F1B)
/// allow_inter_node_tp = false # permit tp > topology.node_size
/// ```
///
/// Mistyped values hard-error like `[exec]`/`[topology]` (a string
/// where an integer belongs, a zero axis, axes that do not factor
/// `cluster.chips`) instead of silently pricing the wrong machine.
/// `tp` must also fit inside a node (`topology.node_size`) unless
/// `allow_inter_node_tp = true`: tensor-parallel collectives sit on
/// every matmul's critical path and are only viable on the intra-node
/// fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MeshConfig {
    /// Data-parallel replicas; `None` = auto (`chips / (tp * pp)`).
    pub dp: Option<usize>,
    /// Tensor-parallel shards per matmul (intra-node axis).
    pub tp: usize,
    /// Pipeline stages (1F1B schedule).
    pub pp: usize,
    /// Permit tensor parallelism to span nodes (priced on the
    /// inter-node link; off by default because it is almost never the
    /// right machine).
    pub allow_inter_node_tp: bool,
}

impl Default for MeshConfig {
    fn default() -> Self {
        MeshConfig { dp: None, tp: 1, pp: 1, allow_inter_node_tp: false }
    }
}

impl MeshConfig {
    /// Resolve into a concrete `cluster::Mesh` over `chips`, filling
    /// the dp axis automatically when unset. The axes must factor the
    /// chip count exactly.
    pub fn resolve(&self, chips: usize) -> Result<crate::cluster::Mesh> {
        let span = self.tp.max(1) * self.pp.max(1);
        let dp = match self.dp {
            Some(dp) => dp,
            None => {
                if chips % span != 0 {
                    bail!(
                        "mesh tp = {} x pp = {} does not divide \
                         cluster.chips = {}; set mesh.dp explicitly or \
                         pick axes that factor the pod",
                        self.tp,
                        self.pp,
                        chips
                    );
                }
                chips / span
            }
        };
        let mesh =
            crate::cluster::Mesh { dp, tp: self.tp.max(1), pp: self.pp.max(1) };
        mesh.validate_chips(chips)?;
        Ok(mesh)
    }
}

/// Which step path the coordinator uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPath {
    /// Per-worker grad artifacts + Rust all-reduce + opt artifact.
    Distributed,
    /// Single fused train-step artifact (fast single-worker path).
    Fused,
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    // model / data
    pub model: String,
    pub seq: usize,
    pub seed: u64,
    // optimization
    pub optimizer: String,
    pub base_lr: Option<f32>, // None => paper sqrt-scaling rule
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub bias_correction: bool,
    pub norm: String,
    // batching
    pub global_batch: usize,
    pub steps: u64,
    pub warmup_ratio: Option<f64>, // None => paper linear-epoch rule
    // cluster
    pub chips: usize,
    pub step_path: StepPath,
    // execution engine ([exec] section)
    /// serial | parallel | zero1 | zero2 | zero3 — how the step loop
    /// drives the workers. `[exec] zero_stage = 0|1|2|3` is an
    /// equivalent spelling (0 keeps the non-ZeRO mode, 1 → zero1,
    /// 2 → zero2, 3 → zero3) and wins when both keys are given.
    pub exec_mode: crate::exec::ExecMode,
    /// Gradient-phase worker count; 0 = auto (min(chips, microbatches)).
    pub exec_workers: usize,
    /// Bucket size for the overlapped all-reduce, in KiB.
    pub bucket_kb: usize,
    /// Gradient-accumulation microbatches per optimizer step
    /// (`[exec] accum_steps`, default 1): each worker runs this many
    /// forward/backward passes before the single bucketed reduce, so
    /// the gradient wire is paid once per accumulated step.
    pub accum_steps: usize,
    // interconnect topology ([topology] section)
    pub topology: TopologyConfig,
    // storage/wire precision ([precision] section)
    pub precision: PrecisionConfig,
    // tracing + telemetry ([trace] section)
    pub trace: TraceConfig,
    // 3D-parallel mesh ([mesh] section)
    pub mesh: MeshConfig,
    // io
    pub artifacts: String,
    pub out_dir: String,
    pub eval_every: u64,
    pub log_every: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "bert-tiny".into(),
            seq: 32,
            seed: 42,
            optimizer: "lamb".into(),
            base_lr: None,
            weight_decay: 0.01,
            beta1: 0.9,
            beta2: 0.999,
            bias_correction: true,
            norm: "l2".into(),
            global_batch: 64,
            steps: 200,
            warmup_ratio: None,
            chips: 8,
            step_path: StepPath::Distributed,
            exec_mode: crate::exec::ExecMode::Serial,
            exec_workers: 0,
            bucket_kb: 1024,
            accum_steps: 1,
            topology: TopologyConfig::default(),
            precision: PrecisionConfig::default(),
            trace: TraceConfig::default(),
            mesh: MeshConfig::default(),
            artifacts: "artifacts".into(),
            out_dir: "results".into(),
            eval_every: 50,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    /// Load from a TOML file and/or `key=value` CLI overrides.
    pub fn load(
        path: Option<&str>,
        overrides: &[(String, String)],
    ) -> Result<TrainConfig> {
        let mut doc = match path {
            Some(p) => parse_toml(
                &std::fs::read_to_string(p)
                    .with_context(|| format!("reading config {p}"))?,
            )?,
            None => TomlDoc::new(),
        };
        for (k, v) in overrides {
            doc.insert(k.clone(), parse_value(v)?);
        }
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<TrainConfig> {
        Self::reject_unknown_keys(doc)?;
        let mut c = TrainConfig::default();
        let gets = |k: &str| -> Option<String> {
            doc.get(k).and_then(|v| v.as_str().map(String::from))
        };
        let getf = |k: &str| doc.get(k).and_then(TomlValue::as_f64);
        let geti = |k: &str| doc.get(k).and_then(TomlValue::as_f64).map(|f| f as u64);
        let getb = |k: &str| doc.get(k).and_then(TomlValue::as_bool);

        if let Some(v) = gets("model.name") { c.model = v; }
        if let Some(v) = geti("model.seq") { c.seq = v as usize; }
        if let Some(v) = geti("run.seed") { c.seed = v; }
        if let Some(v) = gets("optimizer.name") { c.optimizer = v; }
        if let Some(v) = getf("optimizer.lr") { c.base_lr = Some(v as f32); }
        if let Some(v) = getf("optimizer.weight_decay") { c.weight_decay = v as f32; }
        if let Some(v) = getf("optimizer.beta1") { c.beta1 = v as f32; }
        if let Some(v) = getf("optimizer.beta2") { c.beta2 = v as f32; }
        if let Some(v) = getb("optimizer.bias_correction") { c.bias_correction = v; }
        if let Some(v) = gets("optimizer.norm") { c.norm = v; }
        if let Some(v) = geti("batch.global") { c.global_batch = v as usize; }
        if let Some(v) = geti("batch.steps") { c.steps = v; }
        if let Some(v) = getf("batch.warmup_ratio") { c.warmup_ratio = Some(v); }
        if let Some(v) = geti("cluster.chips") { c.chips = v as usize; }
        if let Some(v) = gets("run.step_path") {
            c.step_path = match v.as_str() {
                "distributed" => StepPath::Distributed,
                "fused" => StepPath::Fused,
                other => bail!("unknown step_path {other:?}"),
            };
        }
        if let Some(raw) = doc.get("exec.mode") {
            // Hard-error on a mistyped value (number/bool) instead of
            // silently keeping the default mode.
            let v = raw.as_str().ok_or_else(|| {
                anyhow!(
                    "exec.mode must be a string \
                     \"serial\"|\"parallel\"|\"zero1\"|\"zero2\"|\"zero3\" \
                     (got {raw:?})"
                )
            })?;
            c.exec_mode = crate::exec::ExecMode::parse(v)
                .ok_or_else(|| anyhow!(
                    "unknown exec mode {v:?} \
                     (expected serial|parallel|zero1|zero2|zero3)"
                ))?;
        }
        if let Some(raw) = doc.get("exec.zero_stage") {
            use crate::exec::ExecMode;
            // Hard-error on a mistyped value (float/string/bool) instead
            // of silently running the wrong mode, mirroring exec.mode.
            let v = raw.as_i64().ok_or_else(|| {
                anyhow!(
                    "exec.zero_stage must be an integer 0|1|2|3 (got {raw:?})"
                )
            })?;
            c.exec_mode = match v {
                // Stage 0 keeps a non-ZeRO drive: downgrade a ZeRO mode
                // to the plain pool, leave serial/parallel untouched.
                0 => match c.exec_mode {
                    ExecMode::Zero1 | ExecMode::Zero2 | ExecMode::Zero3 => {
                        ExecMode::Parallel
                    }
                    other => other,
                },
                1 => ExecMode::Zero1,
                2 => ExecMode::Zero2,
                3 => ExecMode::Zero3,
                other => bail!(
                    "exec.zero_stage must be 0, 1, 2 or 3 (got {other})"
                ),
            };
        }
        if let Some(raw) = doc.get("exec.workers") {
            // Hard-error on a mistyped value (float/string/bool) instead
            // of silently auto-sizing the pool, mirroring exec.zero_stage.
            let v = raw.as_i64().ok_or_else(|| {
                anyhow!(
                    "exec.workers must be an integer >= 0 \
                     (0 = auto; got {raw:?})"
                )
            })?;
            if v < 0 {
                bail!("exec.workers must be >= 0 (got {v})");
            }
            c.exec_workers = v as usize;
        }
        if let Some(raw) = doc.get("exec.bucket_kb") {
            // Hard-error on a mistyped value instead of silently keeping
            // the default bucket size, mirroring exec.zero_stage.
            let v = raw.as_i64().ok_or_else(|| {
                anyhow!(
                    "exec.bucket_kb must be an integer >= 1 (got {raw:?})"
                )
            })?;
            if v < 1 {
                bail!("exec.bucket_kb must be >= 1 (got {v})");
            }
            c.bucket_kb = v as usize;
        }
        if let Some(raw) = doc.get("exec.accum_steps") {
            // Hard-error on a mistyped value (float/string/bool) instead
            // of silently accumulating the wrong batch, mirroring
            // exec.zero_stage.
            let v = raw.as_i64().ok_or_else(|| {
                anyhow!(
                    "exec.accum_steps must be an integer >= 1 (got {raw:?})"
                )
            })?;
            if v < 1 {
                bail!("exec.accum_steps must be >= 1 (got {v})");
            }
            c.accum_steps = v as usize;
        }
        // ---- [topology] table: every key hard-errors on a mistyped
        // value (mirroring exec.zero_stage) instead of silently pricing
        // the wrong interconnect. ----
        if let Some(raw) = doc.get("topology.node_size") {
            let v = raw.as_i64().ok_or_else(|| {
                anyhow!("topology.node_size must be an integer (got {raw:?})")
            })?;
            if v < 1 {
                bail!("topology.node_size must be >= 1 (got {v})");
            }
            c.topology.node_size = v as usize;
        }
        // Bandwidths must be strictly positive; latencies may be 0.
        let get_link_f64 =
            |key: &str, strictly_positive: bool| -> Result<Option<f64>> {
                match doc.get(key) {
                    None => Ok(None),
                    Some(raw) => {
                        let v = raw.as_f64().ok_or_else(|| {
                            anyhow!("{key} must be a number (got {raw:?})")
                        })?;
                        if v.is_nan()
                            || v < 0.0
                            || (strictly_positive && v == 0.0)
                        {
                            bail!(
                                "{key} must be {} (got {v})",
                                if strictly_positive {
                                    "positive"
                                } else {
                                    ">= 0"
                                }
                            );
                        }
                        Ok(Some(v))
                    }
                }
            };
        if let Some(v) = get_link_f64("topology.intra_gbps", true)? {
            c.topology.intra_gbps = Some(v);
        }
        if let Some(v) = get_link_f64("topology.inter_gbps", true)? {
            c.topology.inter_gbps = Some(v);
        }
        if let Some(v) = get_link_f64("topology.intra_us", false)? {
            c.topology.intra_us = Some(v);
        }
        if let Some(v) = get_link_f64("topology.inter_us", false)? {
            c.topology.inter_us = Some(v);
        }
        if let Some(raw) = doc.get("topology.schedule") {
            let s = raw.as_str().ok_or_else(|| {
                anyhow!(
                    "topology.schedule must be a string \
                     \"auto\"|\"ring\"|\"hierarchical\"|\"tree\" (got {raw:?})"
                )
            })?;
            c.topology.policy = crate::collective::SchedulePolicy::parse(s)
                .ok_or_else(|| {
                    anyhow!(
                        "unknown topology.schedule {s:?} \
                         (expected auto|ring|hierarchical|tree)"
                    )
                })?;
        }
        if let Some(raw) = doc.get("topology.cross_step") {
            c.topology.cross_step = raw.as_bool().ok_or_else(|| {
                anyhow!("topology.cross_step must be a boolean (got {raw:?})")
            })?;
        }
        // ---- [precision] table: mistyped values hard-error (mirroring
        // exec.zero_stage) instead of silently training the wrong
        // numerics. ----
        let get_precision = |key: &str| -> Result<Option<crate::collective::Precision>> {
            match doc.get(key) {
                None => Ok(None),
                Some(raw) => {
                    let s = raw.as_str().ok_or_else(|| {
                        anyhow!(
                            "{key} must be a string \
                             \"f32\"|\"bf16\"|\"f16\" (got {raw:?})"
                        )
                    })?;
                    Ok(Some(
                        crate::collective::Precision::parse(s).ok_or_else(
                            || {
                                anyhow!(
                                    "unknown {key} {s:?} \
                                     (expected f32|bf16|f16)"
                                )
                            },
                        )?,
                    ))
                }
            }
        };
        if let Some(p) = get_precision("precision.params")? {
            c.precision.params = p;
        }
        if let Some(p) = get_precision("precision.grads")? {
            c.precision.grads = p;
        }
        if let Some(raw) = doc.get("precision.grads_wire") {
            let s = raw.as_str().ok_or_else(|| {
                anyhow!(
                    "precision.grads_wire must be a string \
                     \"f32\"|\"bf16\"|\"f16\"|\"f8\"|\"1bit\" (got {raw:?})"
                )
            })?;
            c.precision.grads_wire = Some(
                crate::collective::Wire::parse(s).ok_or_else(|| {
                    anyhow!(
                        "unknown precision.grads_wire {s:?} \
                         (expected f32|bf16|f16|f8|1bit)"
                    )
                })?,
            );
        }
        if let Some(raw) = doc.get("precision.norms_fp32") {
            c.precision.norms_fp32 = raw.as_bool().ok_or_else(|| {
                anyhow!(
                    "precision.norms_fp32 must be a boolean (got {raw:?})"
                )
            })?;
        }
        if let Some(raw) = doc.get("precision.master_weights") {
            c.precision.master_weights = Some(raw.as_bool().ok_or_else(
                || {
                    anyhow!(
                        "precision.master_weights must be a boolean \
                         (got {raw:?})"
                    )
                },
            )?);
        }
        if let Some(raw) = doc.get("precision.loss_scale") {
            c.precision.loss_scale = match raw {
                TomlValue::Str(s) if s.as_str() == "none" => {
                    LossScaleConfig::None
                }
                TomlValue::Str(s) if s.as_str() == "dynamic" => {
                    LossScaleConfig::Dynamic
                }
                TomlValue::Str(s) => bail!(
                    "unknown precision.loss_scale {s:?} \
                     (expected \"none\", \"dynamic\" or a number >= 1)"
                ),
                other => {
                    let v = other.as_f64().ok_or_else(|| {
                        anyhow!(
                            "precision.loss_scale must be \"none\", \
                             \"dynamic\" or a number >= 1 (got {other:?})"
                        )
                    })?;
                    if !v.is_finite() || v < 1.0 {
                        bail!(
                            "precision.loss_scale must be >= 1 (got {v})"
                        );
                    }
                    // A value above f32 range would pass the f64 check
                    // but become inf at the cast and panic inside
                    // LossScaler later — hard-error at load time.
                    let f = v as f32;
                    if !f.is_finite() {
                        bail!(
                            "precision.loss_scale {v} overflows f32 \
                             (max {:e})",
                            f32::MAX
                        );
                    }
                    LossScaleConfig::Fixed(f)
                }
            };
        }
        // ---- [trace] table: mistyped values hard-error (mirroring
        // [exec]/[topology]) instead of silently dropping telemetry. ----
        let get_trace_bool = |key: &str| -> Result<Option<bool>> {
            match doc.get(key) {
                None => Ok(None),
                Some(raw) => Ok(Some(raw.as_bool().ok_or_else(|| {
                    anyhow!("{key} must be a boolean (got {raw:?})")
                })?)),
            }
        };
        if let Some(v) = get_trace_bool("trace.enabled")? {
            c.trace.enabled = v;
        }
        if let Some(raw) = doc.get("trace.dir") {
            let s = raw.as_str().ok_or_else(|| {
                anyhow!("trace.dir must be a string path (got {raw:?})")
            })?;
            if s.is_empty() {
                bail!("trace.dir must be a non-empty path");
            }
            c.trace.dir = s.to_string();
        }
        if let Some(v) = get_trace_bool("trace.sim_trace")? {
            c.trace.sim_trace = v;
        }
        if let Some(v) = get_trace_bool("trace.host_trace")? {
            c.trace.host_trace = v;
        }
        if let Some(v) = get_trace_bool("trace.metrics_jsonl")? {
            c.trace.metrics_jsonl = v;
        }
        // ---- [mesh] table: mistyped values hard-error (mirroring
        // [exec]/[topology]) instead of silently pricing the wrong
        // parallel machine. ----
        let get_mesh_axis = |key: &str| -> Result<Option<usize>> {
            match doc.get(key) {
                None => Ok(None),
                Some(raw) => {
                    let v = raw.as_i64().ok_or_else(|| {
                        anyhow!("{key} must be an integer (got {raw:?})")
                    })?;
                    if v < 1 {
                        bail!("{key} must be >= 1 (got {v})");
                    }
                    Ok(Some(v as usize))
                }
            }
        };
        if let Some(v) = get_mesh_axis("mesh.dp")? {
            c.mesh.dp = Some(v);
        }
        if let Some(v) = get_mesh_axis("mesh.tp")? {
            c.mesh.tp = v;
        }
        if let Some(v) = get_mesh_axis("mesh.pp")? {
            c.mesh.pp = v;
        }
        if let Some(raw) = doc.get("mesh.allow_inter_node_tp") {
            c.mesh.allow_inter_node_tp = raw.as_bool().ok_or_else(|| {
                anyhow!(
                    "mesh.allow_inter_node_tp must be a boolean (got {raw:?})"
                )
            })?;
        }
        if let Some(v) = gets("run.artifacts") { c.artifacts = v; }
        if let Some(v) = gets("run.out_dir") { c.out_dir = v; }
        if let Some(v) = geti("run.eval_every") { c.eval_every = v; }
        if let Some(v) = geti("run.log_every") { c.log_every = v; }
        c.validate()?;
        Ok(c)
    }

    /// Every key the five strict tables document. The tables whose
    /// values already hard-error on mistypes also reject *unknown*
    /// keys: a typo'd key name (`bucket_mb`, `zerostage`) is the same
    /// failure mode as a typo'd value and must not silently fall back
    /// to a default. Legacy sections (`model.`/`run.`/`batch.`/
    /// `cluster.`/`optimizer.`) predate the strict regime and stay
    /// lenient for sweep-script compatibility.
    const KNOWN_STRICT_KEYS: &'static [(&'static str, &'static [&'static str])] = &[
        (
            "exec",
            &["mode", "workers", "bucket_kb", "zero_stage", "accum_steps"],
        ),
        (
            "topology",
            &[
                "node_size",
                "intra_gbps",
                "inter_gbps",
                "intra_us",
                "inter_us",
                "schedule",
                "cross_step",
            ],
        ),
        (
            "precision",
            &[
                "params",
                "grads",
                "grads_wire",
                "master_weights",
                "loss_scale",
                "norms_fp32",
            ],
        ),
        (
            "trace",
            &["enabled", "dir", "sim_trace", "host_trace", "metrics_jsonl"],
        ),
        ("mesh", &["dp", "tp", "pp", "allow_inter_node_tp"]),
    ];

    fn reject_unknown_keys(doc: &TomlDoc) -> Result<()> {
        for full in doc.keys() {
            let Some((section, key)) = full.split_once('.') else {
                continue;
            };
            let Some((_, known)) = Self::KNOWN_STRICT_KEYS
                .iter()
                .find(|(s, _)| *s == section)
            else {
                continue;
            };
            if !known.contains(&key) {
                bail!(
                    "unknown key {full:?} in the strict [{section}] \
                     table (known keys: {})",
                    known.join(", ")
                );
            }
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.global_batch == 0 || self.steps == 0 || self.chips == 0 {
            bail!("batch/steps/chips must be positive");
        }
        if crate::optim::build(&self.optimizer, 1, Default::default()).is_none() {
            bail!(
                "unknown optimizer {:?} (expected one of {:?})",
                self.optimizer,
                crate::optim::ALL
            );
        }
        if crate::optim::Norm::parse(&self.norm).is_none() {
            bail!("unknown norm {:?}", self.norm);
        }
        if self.bucket_kb == 0 {
            bail!("exec.bucket_kb must be positive");
        }
        // Mesh axes must factor the pod, and tp must fit inside a node
        // unless explicitly overridden (cross-field with [topology]).
        // Model-dependent rules (pp vs layer count, tp vs attention
        // heads) are checked by the coordinator once the model is
        // known.
        self.mesh.resolve(self.chips)?;
        if self.mesh.tp > self.topology.node_size.max(1)
            && !self.mesh.allow_inter_node_tp
        {
            bail!(
                "mesh.tp = {} exceeds topology.node_size = {}: \
                 tensor-parallel collectives would cross the inter-node \
                 link on every matmul; shrink tp, raise \
                 topology.node_size, or set mesh.allow_inter_node_tp = \
                 true to price it anyway",
                self.mesh.tp,
                self.topology.node_size
            );
        }
        use crate::collective::Precision;
        if self.precision.params != Precision::F32
            && self.exec_mode.zero_stage() < 2
        {
            bail!(
                "[precision] params = \"{}\" requires zero_stage >= 2: the \
                 fp32 master-weight step path lives in the ZeRO-2/3 \
                 sharded states (set [exec] zero_stage = 2 or 3, or keep \
                 params = \"f32\")",
                self.precision.params.as_str()
            );
        }
        if self.precision.master_weights == Some(false)
            && self.precision.params != Precision::F32
        {
            bail!(
                "half-width params require fp32 master weights \
                 (master_weights = false is only valid with \
                 params = \"f32\")"
            );
        }
        // The fused single-artifact path steps the dense optimizer
        // inside the artifact: no gradient wire to quantize, no seam
        // for the scaler's skip-and-halve gate, and no way to honor
        // ZeRO sharding (the trainer would also checkpoint the
        // never-stepped shard state instead of the artifact-held
        // moments) — reject the dead knobs instead of silently
        // ignoring them. Rejecting zero_stage >= 1 here also closes
        // the fused + half-params hole: half params require stage >= 2.
        if self.step_path == StepPath::Fused {
            if self.exec_mode.zero_stage() >= 1 {
                bail!(
                    "step_path = \"fused\" is incompatible with \
                     exec mode {} (the fused artifact steps the dense \
                     optimizer; ZeRO shard state would never be \
                     stepped); use the distributed step path",
                    self.exec_mode.as_str()
                );
            }
            if self.precision.loss_scale != LossScaleConfig::None {
                bail!(
                    "step_path = \"fused\" is incompatible with \
                     precision.loss_scale (the fused artifact steps the \
                     optimizer internally, bypassing the scaler gate); \
                     use the distributed step path"
                );
            }
            if self.precision.grads != Precision::F32 {
                bail!(
                    "step_path = \"fused\" is incompatible with \
                     precision.grads = \"{}\" (the single fused worker \
                     has no gradient wire); use the distributed step \
                     path",
                    self.precision.grads.as_str()
                );
            }
            if self.precision.plan().compressed_wire() {
                bail!(
                    "step_path = \"fused\" is incompatible with \
                     precision.grads_wire = \"{}\" (the single fused \
                     worker has no gradient wire to compress); use the \
                     distributed step path",
                    self.precision.plan().wire().as_str()
                );
            }
            if self.accum_steps > 1 {
                bail!(
                    "step_path = \"fused\" is incompatible with \
                     exec.accum_steps = {} (the fused artifact runs one \
                     forward/backward per step — there is no microbatch \
                     loop to accumulate over); use the distributed step \
                     path",
                    self.accum_steps
                );
            }
        }
        Ok(())
    }

    /// The effective schedule per the paper's untuned recipe (or the
    /// explicit overrides).
    pub fn schedule(&self) -> crate::schedule::Schedule {
        let base = self.base_lr.unwrap_or_else(|| {
            crate::schedule::sqrt_scaled_lr(0.005, 32768, self.global_batch)
        });
        let ratio = self
            .warmup_ratio
            .unwrap_or_else(|| crate::schedule::warmup_ratio(self.global_batch))
            .min(0.5);
        let warmup = ((self.steps as f64) * ratio).round().max(1.0) as u64;
        crate::schedule::Schedule::WarmupPoly {
            base,
            warmup,
            total: self.steps,
            power: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_subset() {
        let doc = parse_toml(
            r#"
# comment
top = 1
[model]
name = "bert-small"   # trailing comment
seq = 128
[optimizer]
lr = 2.5e-3
bias_correction = false
betas = [0.9, 0.999]
"#,
        )
        .unwrap();
        assert_eq!(doc["top"], TomlValue::Int(1));
        assert_eq!(doc["model.name"].as_str(), Some("bert-small"));
        assert_eq!(doc["optimizer.lr"].as_f64(), Some(2.5e-3));
        assert_eq!(doc["optimizer.bias_correction"].as_bool(), Some(false));
        match &doc["optimizer.betas"] {
            TomlValue::Arr(a) => assert_eq!(a.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn toml_errors() {
        assert!(parse_toml("key").is_err());
        assert!(parse_toml("k = @@").is_err());
    }

    #[test]
    fn config_defaults_and_overrides() {
        let c = TrainConfig::load(
            None,
            &[
                ("optimizer.name".into(), "\"lars\"".into()),
                ("batch.global".into(), "512".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.optimizer, "lars");
        assert_eq!(c.global_batch, 512);
        assert_eq!(c.model, "bert-tiny");
    }

    #[test]
    fn config_rejects_unknown_optimizer() {
        let r = TrainConfig::load(
            None,
            &[("optimizer.name".into(), "\"sgdx\"".into())],
        );
        assert!(r.is_err());
        // the 54-minute-trajectory optimizer is a first-class name
        let c = TrainConfig::load(
            None,
            &[("optimizer.name".into(), "\"lans\"".into())],
        )
        .unwrap();
        assert_eq!(c.optimizer, "lans");
    }

    #[test]
    fn exec_knobs_parse_and_validate() {
        let c = TrainConfig::load(
            None,
            &[
                ("exec.mode".into(), "\"zero1\"".into()),
                ("exec.workers".into(), "4".into()),
                ("exec.bucket_kb".into(), "256".into()),
                ("exec.accum_steps".into(), "4".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.exec_mode, crate::exec::ExecMode::Zero1);
        assert_eq!(c.exec_workers, 4);
        assert_eq!(c.bucket_kb, 256);
        assert_eq!(c.accum_steps, 4);
        // defaults: serial, auto workers, no accumulation
        let d = TrainConfig::default();
        assert_eq!(d.exec_mode, crate::exec::ExecMode::Serial);
        assert_eq!(d.exec_workers, 0);
        assert_eq!(d.accum_steps, 1);
        // bad mode rejected
        assert!(TrainConfig::load(
            None,
            &[("exec.mode".into(), "\"async\"".into())]
        )
        .is_err());
        // accum_steps: mistypes and zero hard-error like zero_stage
        let bad = |v: &str| {
            TrainConfig::load(
                None,
                &[("exec.accum_steps".into(), v.into())],
            )
            .is_err()
        };
        assert!(bad("0"));
        assert!(bad("-2"));
        assert!(bad("2.0"));
        assert!(bad("\"4\""));
        assert!(bad("true"));
        // the fused path has no microbatch loop to accumulate over
        assert!(TrainConfig::load(
            None,
            &[
                ("run.step_path".into(), "\"fused\"".into()),
                ("exec.accum_steps".into(), "2".into()),
            ]
        )
        .is_err());
    }

    #[test]
    fn zero_stage_knob_maps_to_exec_mode() {
        use crate::exec::ExecMode;
        let stage = |n: &str| {
            TrainConfig::load(None, &[("exec.zero_stage".into(), n.into())])
                .map(|c| c.exec_mode)
        };
        assert_eq!(stage("1").unwrap(), ExecMode::Zero1);
        assert_eq!(stage("2").unwrap(), ExecMode::Zero2);
        assert_eq!(stage("3").unwrap(), ExecMode::Zero3);
        // stage 0 on the default (serial) config keeps serial
        assert_eq!(stage("0").unwrap(), ExecMode::Serial);
        assert!(stage("4").is_err());
        // mistyped values are errors, not silently-ignored keys
        assert!(stage("2.0").is_err());
        assert!(stage("3.0").is_err());
        assert!(stage("\"2\"").is_err());
        assert!(stage("\"3\"").is_err());
        assert!(stage("true").is_err());
        // zero_stage wins over exec.mode when both are given
        let c = TrainConfig::load(
            None,
            &[
                ("exec.mode".into(), "\"zero1\"".into()),
                ("exec.zero_stage".into(), "2".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.exec_mode, ExecMode::Zero2);
        // ...including the downgrade direction: stage 0 over a ZeRO mode
        // falls back to the plain parallel pool
        let c = TrainConfig::load(
            None,
            &[
                ("exec.mode".into(), "\"zero3\"".into()),
                ("exec.zero_stage".into(), "0".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.exec_mode, ExecMode::Parallel);
        // "zero2"/"zero3" parse as plain mode strings too
        let c = TrainConfig::load(
            None,
            &[("exec.mode".into(), "\"zero2\"".into())],
        )
        .unwrap();
        assert_eq!(c.exec_mode, ExecMode::Zero2);
        let c = TrainConfig::load(
            None,
            &[("exec.mode".into(), "\"zero3\"".into())],
        )
        .unwrap();
        assert_eq!(c.exec_mode, ExecMode::Zero3);
    }

    #[test]
    fn topology_table_parses_and_builds() {
        use crate::collective::{RingCost, ScheduleKind, SchedulePolicy};
        let c = TrainConfig::load(
            None,
            &[
                ("topology.node_size".into(), "8".into()),
                ("topology.intra_gbps".into(), "600.0".into()),
                ("topology.inter_gbps".into(), "70.0".into()),
                ("topology.intra_us".into(), "1.0".into()),
                ("topology.schedule".into(), "\"auto\"".into()),
                ("topology.cross_step".into(), "true".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.topology.node_size, 8);
        assert_eq!(c.topology.policy, SchedulePolicy::Auto);
        assert!(c.topology.cross_step);
        let base = RingCost { alpha: 4.4e-5, beta: 70e9 };
        let topo = c.topology.build(base);
        assert_eq!(topo.node_size, 8);
        assert_eq!(topo.intra.beta, 600e9);
        assert_eq!(topo.intra.alpha, 1e-6);
        // unset inter latency inherits the base link bit-for-bit
        assert_eq!(topo.inter.alpha.to_bits(), base.alpha.to_bits());
        assert_eq!(topo.inter.beta, 70e9);

        // Defaults: absent table = flat ring over the base link, exactly.
        let d = TrainConfig::default();
        let flat = d.topology.build(base);
        assert_eq!(flat.node_size, 1);
        assert_eq!(flat.policy, SchedulePolicy::Fixed(ScheduleKind::Ring));
        assert!(!flat.cross_step);
        assert_eq!(flat.intra.alpha.to_bits(), base.alpha.to_bits());
        assert_eq!(flat.inter.beta.to_bits(), base.beta.to_bits());

        // fixed kinds parse too
        for kind in ["ring", "hierarchical", "tree"] {
            let c = TrainConfig::load(
                None,
                &[("topology.schedule".into(), format!("\"{kind}\""))],
            )
            .unwrap();
            assert_eq!(c.topology.policy.as_str(), kind);
        }
    }

    /// Mistyped `[topology]` values are hard errors (like
    /// `exec.zero_stage`), never silently-ignored keys.
    #[test]
    fn topology_table_rejects_mistyped_values() {
        let bad = |k: &str, v: &str| {
            TrainConfig::load(None, &[(k.into(), v.into())]).is_err()
        };
        // wrong type
        assert!(bad("topology.node_size", "8.0"));
        assert!(bad("topology.node_size", "\"8\""));
        assert!(bad("topology.node_size", "true"));
        assert!(bad("topology.intra_gbps", "\"600\""));
        assert!(bad("topology.inter_gbps", "false"));
        assert!(bad("topology.intra_us", "\"1us\""));
        assert!(bad("topology.schedule", "2"));
        assert!(bad("topology.schedule", "true"));
        assert!(bad("topology.cross_step", "1"));
        assert!(bad("topology.cross_step", "\"yes\""));
        // wrong value
        assert!(bad("topology.node_size", "0"));
        assert!(bad("topology.node_size", "-8"));
        assert!(bad("topology.intra_gbps", "0"));
        assert!(bad("topology.inter_gbps", "-70.0"));
        assert!(bad("topology.inter_us", "-1.0"));
        assert!(bad("topology.schedule", "\"mesh\""));
        // integers are fine where floats are expected
        let c = TrainConfig::load(
            None,
            &[("topology.inter_gbps".into(), "70".into())],
        )
        .unwrap();
        assert_eq!(c.topology.inter_gbps, Some(70.0));
    }

    #[test]
    fn precision_table_parses_and_resolves() {
        use crate::collective::Precision;
        let c = TrainConfig::load(
            None,
            &[
                ("exec.zero_stage".into(), "3".into()),
                ("precision.params".into(), "\"bf16\"".into()),
                ("precision.grads".into(), "\"bf16\"".into()),
                ("precision.loss_scale".into(), "\"dynamic\"".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.precision.params, Precision::Bf16);
        assert_eq!(c.precision.grads, Precision::Bf16);
        assert_eq!(c.precision.master_weights, None);
        assert_eq!(c.precision.loss_scale, LossScaleConfig::Dynamic);
        let plan = c.precision.plan();
        assert!(plan.has_master(), "half params force the master copy");
        assert!(plan.is_mixed());
        assert_eq!(c.precision.scaler().unwrap().scale(), 65536.0);
        // fixed scale spelled as a number (integers work like floats)
        let c = TrainConfig::load(
            None,
            &[("precision.loss_scale".into(), "1024".into())],
        )
        .unwrap();
        assert_eq!(c.precision.loss_scale, LossScaleConfig::Fixed(1024.0));
        assert_eq!(c.precision.scaler().unwrap().scale(), 1024.0);
        // defaults: pure f32, no scaler, plan == F32 baseline
        let d = TrainConfig::default();
        assert_eq!(d.precision.plan(), crate::collective::PrecisionPlan::F32);
        assert!(d.precision.scaler().is_none());
        // grads-only mixed works at any stage (wire quantization needs
        // no master copy)
        let c = TrainConfig::load(
            None,
            &[("precision.grads".into(), "\"f16\"".into())],
        )
        .unwrap();
        assert_eq!(c.precision.grads, Precision::F16);
        assert!(!c.precision.plan().has_master());
        // compressed gradient wire: storage stays f32, only the
        // collective payload narrows (error-feedback makes it safe)
        use crate::collective::Wire;
        for (spelling, wire) in [("\"f8\"", Wire::F8), ("\"1bit\"", Wire::OneBit)]
        {
            let c = TrainConfig::load(
                None,
                &[("precision.grads_wire".into(), spelling.into())],
            )
            .unwrap();
            assert_eq!(c.precision.grads_wire, Some(wire));
            assert_eq!(c.precision.plan().wire(), wire);
            assert!(c.precision.plan().compressed_wire());
            assert_eq!(c.precision.grads, Precision::F32);
        }
        // unset wire derives from grads storage
        let c = TrainConfig::load(
            None,
            &[("precision.grads".into(), "\"bf16\"".into())],
        )
        .unwrap();
        assert_eq!(c.precision.grads_wire, None);
        assert_eq!(c.precision.plan().wire(), Wire::Bf16);
        assert!(!c.precision.plan().compressed_wire());
        // norms_fp32: off by default, parses as a boolean, flows into
        // the plan; mistypes hard-error
        assert!(!TrainConfig::default().precision.norms_fp32);
        let c = TrainConfig::load(
            None,
            &[
                ("exec.zero_stage".into(), "3".into()),
                ("precision.params".into(), "\"bf16\"".into()),
                ("precision.norms_fp32".into(), "true".into()),
            ],
        )
        .unwrap();
        assert!(c.precision.norms_fp32);
        assert!(c.precision.plan().norms_fp32);
        for v in ["1", "\"yes\"", "2.0"] {
            assert!(TrainConfig::load(
                None,
                &[("precision.norms_fp32".into(), v.into())]
            )
            .is_err());
        }
    }

    /// Mistyped `[precision]` values are hard errors (like
    /// `exec.zero_stage`), never silently-ignored keys — including the
    /// consistency rules (half params need stage >= 2 and masters).
    #[test]
    fn precision_table_rejects_mistypes_and_inconsistency() {
        let bad = |kv: &[(&str, &str)]| {
            let kv: Vec<(String, String)> = kv
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect();
            TrainConfig::load(None, &kv).is_err()
        };
        // wrong type
        assert!(bad(&[("precision.params", "16")]));
        assert!(bad(&[("precision.params", "true")]));
        assert!(bad(&[("precision.grads", "2.0")]));
        assert!(bad(&[("precision.master_weights", "\"yes\"")]));
        assert!(bad(&[("precision.master_weights", "1")]));
        assert!(bad(&[("precision.loss_scale", "true")]));
        // wrong value
        assert!(bad(&[("precision.params", "\"fp8\"")]));
        assert!(bad(&[("precision.grads", "\"half\"")]));
        assert!(bad(&[("precision.grads_wire", "8")]));
        assert!(bad(&[("precision.grads_wire", "true")]));
        assert!(bad(&[("precision.grads_wire", "\"2bit\"")]));
        assert!(bad(&[("precision.grads_wire", "\"int8\"")]));
        assert!(bad(&[("precision.loss_scale", "\"auto\"")]));
        assert!(bad(&[("precision.loss_scale", "0.5")]));
        assert!(bad(&[("precision.loss_scale", "-2")]));
        // above f32 range: would become inf at the cast and panic in
        // LossScaler — must hard-error at load time instead
        assert!(bad(&[("precision.loss_scale", "1e39")]));
        // the fused step path has no wire, no scaler seam, and steps
        // the dense optimizer (ZeRO shard state would rot unstepped —
        // which also closes the fused + half-params route, since half
        // params require stage >= 2)
        assert!(bad(&[
            ("run.step_path", "\"fused\""),
            ("precision.loss_scale", "\"dynamic\""),
        ]));
        assert!(bad(&[
            ("run.step_path", "\"fused\""),
            ("precision.grads", "\"bf16\""),
        ]));
        assert!(bad(&[
            ("run.step_path", "\"fused\""),
            ("precision.grads_wire", "\"1bit\""),
        ]));
        for stage in ["1", "2", "3"] {
            assert!(bad(&[
                ("run.step_path", "\"fused\""),
                ("exec.zero_stage", stage),
            ]));
        }
        assert!(bad(&[
            ("run.step_path", "\"fused\""),
            ("exec.zero_stage", "2"),
            ("precision.params", "\"bf16\""),
        ]));
        // ...but fused + pure f32 stays accepted
        let c = TrainConfig::load(
            None,
            &[("run.step_path".into(), "\"fused\"".into())],
        )
        .unwrap();
        assert_eq!(c.step_path, StepPath::Fused);
        // half params below stage 2: no master step path exists there
        assert!(bad(&[("precision.params", "\"bf16\"")]));
        assert!(bad(&[
            ("precision.params", "\"f16\""),
            ("exec.zero_stage", "1"),
        ]));
        // ...but stage 2 and 3 accept them
        for stage in ["2", "3"] {
            let c = TrainConfig::load(
                None,
                &[
                    ("precision.params".into(), "\"bf16\"".into()),
                    ("exec.zero_stage".into(), stage.into()),
                ],
            )
            .unwrap();
            assert!(c.precision.plan().has_master());
        }
        // explicitly disabling masters with half params is inconsistent
        assert!(bad(&[
            ("precision.params", "\"bf16\""),
            ("exec.zero_stage", "3"),
            ("precision.master_weights", "false"),
        ]));
        // explicit opt-in with f32 params is fine
        let c = TrainConfig::load(
            None,
            &[("precision.master_weights".into(), "true".into())],
        )
        .unwrap();
        assert!(c.precision.plan().has_master());
    }

    #[test]
    fn trace_table_parses_with_defaults() {
        // Absent table: disabled, canonical defaults.
        let d = TrainConfig::default();
        assert!(!d.trace.enabled);
        assert_eq!(d.trace.dir, "results/trace");
        assert!(d.trace.sim_trace);
        assert!(d.trace.host_trace);
        assert!(d.trace.metrics_jsonl);
        let c = TrainConfig::load(
            None,
            &[
                ("trace.enabled".into(), "true".into()),
                ("trace.dir".into(), "\"out/tr\"".into()),
                ("trace.sim_trace".into(), "false".into()),
                ("trace.host_trace".into(), "true".into()),
                ("trace.metrics_jsonl".into(), "false".into()),
            ],
        )
        .unwrap();
        assert!(c.trace.enabled);
        assert_eq!(c.trace.dir, "out/tr");
        assert!(!c.trace.sim_trace);
        assert!(c.trace.host_trace);
        assert!(!c.trace.metrics_jsonl);
    }

    /// Mistyped `[trace]` values are hard errors (like `exec.zero_stage`
    /// and the `[topology]`/`[precision]` tables), never silently-ignored
    /// keys.
    #[test]
    fn trace_table_rejects_mistyped_values() {
        let bad = |k: &str, v: &str| {
            TrainConfig::load(None, &[(k.into(), v.into())]).is_err()
        };
        assert!(bad("trace.enabled", "\"yes\""));
        assert!(bad("trace.enabled", "1"));
        assert!(bad("trace.dir", "7"));
        assert!(bad("trace.dir", "true"));
        assert!(bad("trace.dir", "\"\""));
        assert!(bad("trace.sim_trace", "\"true\""));
        assert!(bad("trace.host_trace", "0"));
        assert!(bad("trace.metrics_jsonl", "1.0"));
    }

    #[test]
    fn mesh_table_parses_resolves_and_defaults_to_pure_dp() {
        // Absent table: pure dp over all chips, bitwise-degenerate.
        let d = TrainConfig::default();
        assert_eq!(d.mesh, MeshConfig::default());
        let mesh = d.mesh.resolve(d.chips).unwrap();
        assert!(mesh.is_pure_dp());
        assert_eq!(mesh.dp, d.chips);
        // Explicit axes; dp auto-fills to chips / (tp * pp).
        let c = TrainConfig::load(
            None,
            &[
                ("cluster.chips".into(), "1024".into()),
                ("topology.node_size".into(), "8".into()),
                ("mesh.tp".into(), "4".into()),
                ("mesh.pp".into(), "2".into()),
            ],
        )
        .unwrap();
        let mesh = c.mesh.resolve(c.chips).unwrap();
        assert_eq!((mesh.dp, mesh.tp, mesh.pp), (128, 4, 2));
        assert_eq!(mesh.label(), "dp128-tp4-pp2");
        // Explicit dp must factor exactly too.
        let c = TrainConfig::load(
            None,
            &[
                ("cluster.chips".into(), "1024".into()),
                ("topology.node_size".into(), "8".into()),
                ("mesh.dp".into(), "256".into()),
                ("mesh.tp".into(), "4".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.mesh.dp, Some(256));
        assert!(c.mesh.resolve(1024).is_ok());
        assert!(c.mesh.resolve(512).is_err());
    }

    /// Mistyped `[mesh]` values are hard errors (like `exec.zero_stage`
    /// and every other table), and so are axes that do not factor the
    /// pod or a tp that escapes the node without the explicit override.
    #[test]
    fn mesh_table_rejects_mistypes_and_infeasible_axes() {
        let bad = |kv: &[(&str, &str)]| {
            let kv: Vec<(String, String)> = kv
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect();
            TrainConfig::load(None, &kv).is_err()
        };
        // wrong type
        assert!(bad(&[("mesh.dp", "\"8\"")]));
        assert!(bad(&[("mesh.tp", "2.0")]));
        assert!(bad(&[("mesh.pp", "true")]));
        assert!(bad(&[("mesh.allow_inter_node_tp", "\"yes\"")]));
        assert!(bad(&[("mesh.allow_inter_node_tp", "1")]));
        // wrong value
        assert!(bad(&[("mesh.dp", "0")]));
        assert!(bad(&[("mesh.tp", "-2")]));
        assert!(bad(&[("mesh.pp", "0")]));
        // axes must factor cluster.chips (default 8)
        assert!(bad(&[
            ("mesh.tp", "2"),
            ("mesh.pp", "3"),
            ("topology.node_size", "8"),
        ]));
        assert!(bad(&[
            ("mesh.dp", "8"),
            ("mesh.tp", "2"),
            ("topology.node_size", "8"),
        ]));
        // tp beyond the node needs the explicit override
        let err = TrainConfig::load(
            None,
            &[
                ("cluster.chips".into(), "1024".into()),
                ("topology.node_size".into(), "8".into()),
                ("mesh.tp".into(), "16".into()),
            ],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("node_size"), "{err}");
        assert!(err.contains("allow_inter_node_tp"), "{err}");
        let c = TrainConfig::load(
            None,
            &[
                ("cluster.chips".into(), "1024".into()),
                ("topology.node_size".into(), "8".into()),
                ("mesh.tp".into(), "16".into()),
                ("mesh.allow_inter_node_tp".into(), "true".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.mesh.resolve(1024).unwrap().tp, 16);
        // the default topology is flat (node_size 1), so any tp > 1
        // needs the override there too
        assert!(bad(&[("mesh.tp", "2")]));
    }

    /// Table-driven sweep over EVERY documented key of the five strict
    /// tables (`[exec]`/`[topology]`/`[precision]`/`[trace]`/`[mesh]`):
    /// each key accepts a well-typed value and hard-errors on a
    /// mistyped one, and each section rejects unknown key names. The
    /// table below must stay in sync with `KNOWN_STRICT_KEYS` — the
    /// final assert enforces that mechanically, so adding a config key
    /// without extending this test fails loudly.
    #[test]
    fn strict_tables_reject_mistypes_and_unknown_keys_exhaustively() {
        // (key, well-typed value, mistyped value, companion overrides
        // the good value needs to pass cross-field validation)
        let cases: &[(&str, &str, &str, &[(&str, &str)])] = &[
            ("exec.mode", "\"parallel\"", "2", &[]),
            ("exec.workers", "4", "\"4\"", &[]),
            ("exec.bucket_kb", "256", "2.5", &[]),
            ("exec.zero_stage", "2", "\"2\"", &[]),
            ("exec.accum_steps", "4", "true", &[]),
            ("topology.node_size", "8", "\"8\"", &[]),
            ("topology.intra_gbps", "600.0", "true", &[]),
            ("topology.inter_gbps", "70.0", "\"70\"", &[]),
            ("topology.intra_us", "1.0", "false", &[]),
            ("topology.inter_us", "44.0", "\"44us\"", &[]),
            ("topology.schedule", "\"auto\"", "3", &[]),
            ("topology.cross_step", "true", "1", &[]),
            (
                "precision.params",
                "\"bf16\"",
                "32",
                &[("exec.zero_stage", "2")],
            ),
            ("precision.grads", "\"bf16\"", "true", &[]),
            ("precision.grads_wire", "\"1bit\"", "8", &[]),
            ("precision.master_weights", "true", "\"no\"", &[]),
            ("precision.loss_scale", "\"dynamic\"", "true", &[]),
            ("precision.norms_fp32", "false", "\"on\"", &[]),
            ("trace.enabled", "true", "1", &[]),
            ("trace.dir", "\"out/tr\"", "3", &[]),
            ("trace.sim_trace", "false", "\"t\"", &[]),
            ("trace.host_trace", "true", "0", &[]),
            ("trace.metrics_jsonl", "false", "2.0", &[]),
            ("mesh.dp", "8", "\"8\"", &[]),
            ("mesh.tp", "1", "1.5", &[]),
            ("mesh.pp", "1", "false", &[]),
            ("mesh.allow_inter_node_tp", "true", "\"y\"", &[]),
        ];
        let load = |kv: &[(&str, &str)]| {
            let kv: Vec<(String, String)> = kv
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect();
            TrainConfig::load(None, &kv)
        };
        for &(key, good, bad, companions) in cases {
            let mut kv = companions.to_vec();
            kv.push((key, good));
            load(&kv).unwrap_or_else(|e| {
                panic!("{key} = {good} must parse: {e}")
            });
            assert!(
                load(&[(key, bad)]).is_err(),
                "{key} = {bad} (mistyped) must hard-error"
            );
        }
        // Unknown keys in a strict table are the same failure mode as
        // a mistyped value: hard errors naming the known key set.
        for (section, typo) in [
            ("exec", "bucket_mb"),
            ("topology", "nodesize"),
            ("precision", "parms"),
            ("trace", "enable"),
            ("mesh", "dpp"),
        ] {
            let key = format!("{section}.{typo}");
            let err = load(&[(&key, "1")])
                .expect_err("unknown strict-table key must error")
                .to_string();
            assert!(err.contains(&key), "{err}");
            assert!(err.contains("known keys"), "{err}");
        }
        // Legacy sections stay lenient: unknown keys there are ignored
        // (sweep scripts attach free-form metadata).
        load(&[("run.annotation", "\"v3\""), ("optimizer.momentum", "0.9")])
            .expect("non-strict sections remain lenient");
        // The case table covers every documented key, so a new
        // KNOWN_STRICT_KEYS entry without a test case fails here.
        let documented: usize = TrainConfig::KNOWN_STRICT_KEYS
            .iter()
            .map(|(_, keys)| keys.len())
            .sum();
        assert_eq!(cases.len(), documented, "case table out of sync");
        for &(key, _, _, _) in cases {
            let (section, k) = key.split_once('.').unwrap();
            assert!(
                TrainConfig::KNOWN_STRICT_KEYS
                    .iter()
                    .any(|(s, keys)| *s == section && keys.contains(&k)),
                "{key} missing from KNOWN_STRICT_KEYS"
            );
        }
    }

    #[test]
    fn schedule_uses_paper_rules_by_default() {
        let mut c = TrainConfig::default();
        c.global_batch = 32768;
        c.steps = 15625;
        if let crate::schedule::Schedule::WarmupPoly { base, warmup, .. } =
            c.schedule()
        {
            assert!((base - 0.005).abs() < 1e-9);
            assert_eq!(warmup, 3125);
        } else {
            panic!();
        }
    }
}
