//! CLI for the determinism linter ([`lamb_train::detlint`]).
//!
//! ```text
//! detlint [--root <dir>] [--json <path>]
//! ```
//!
//! Scans every `.rs` file under the source root (auto-detected:
//! `rust/src` from the repository root, `src` from `rust/`), prints
//! human-readable findings, optionally writes the machine-readable
//! report, and exits nonzero if any violation fired — the CI gate.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use lamb_train::detlint;

const USAGE: &str = "usage: detlint [--root <dir>] [--json <path>]
  --root <dir>   source root to scan (default: rust/src, else src)
  --json <path>  also write the machine-readable report to <path>
  --rules        print the rule table and exit";

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut json_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage_err("--root needs a value"),
            },
            "--json" => match args.next() {
                Some(v) => json_path = Some(PathBuf::from(v)),
                None => return usage_err("--json needs a value"),
            },
            "--rules" => {
                for r in detlint::RULES {
                    println!("{:<16} {}", r.id, r.summary);
                    println!("{:<16}   scope: {}", "", r.scope);
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                return usage_err(&format!("unknown argument {other:?}"))
            }
        }
    }

    let root = match root {
        Some(r) => r,
        None => {
            let candidates = ["rust/src", "src"];
            match candidates
                .iter()
                .map(Path::new)
                .find(|p| p.is_dir())
            {
                Some(p) => p.to_path_buf(),
                None => {
                    eprintln!(
                        "detlint: no source root found (tried \
                         {candidates:?}); pass --root"
                    );
                    return ExitCode::from(2);
                }
            }
        }
    };

    let report = match detlint::scan_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: scanning {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    if let Some(path) = &json_path {
        if let Err(e) = std::fs::write(path, report.to_json()) {
            eprintln!(
                "detlint: writing report {}: {e}",
                path.display()
            );
            return ExitCode::from(2);
        }
    }

    for v in &report.violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.snippet);
    }
    println!(
        "detlint: {} file(s), {} violation(s), {} audited allow(s)",
        report.files_scanned,
        report.violations.len(),
        report.allows.len()
    );
    if report.violations.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn usage_err(msg: &str) -> ExitCode {
    eprintln!("detlint: {msg}\n{USAGE}");
    ExitCode::from(2)
}
