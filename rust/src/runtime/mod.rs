//! Artifact runtime: loads the AOT-compiled HLO-text artifacts and
//! executes them. Two interchangeable backends behind one API:
//!
//! * [`pjrt`] (feature `pjrt`) — the real thing: the `xla` crate's PJRT
//!   CPU client. This is the only place that crate is touched; the rest
//!   of the coordinator works with plain `Vec<f32>` / `Vec<i32>` host
//!   buffers.
//! * [`stub`] (default) — an offline stand-in with the identical surface
//!   whose `Engine::cpu()` fails with a clear "rebuild with `--features
//!   pjrt`" error. Everything that does not execute artifacts (the native
//!   trainer, the exec engine, the pod model, the sweeps) works fully in
//!   this configuration; the BERT-artifact paths fail at run time, not at
//!   compile time.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;
