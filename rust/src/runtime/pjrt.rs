//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them on the CPU PJRT client. This is the only place the `xla` crate is
//! touched; the rest of the coordinator works with plain `Vec<f32>` /
//! `Vec<i32>` host buffers.
//!
//! HLO *text* is the interchange format (not serialized protos): jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

struct ExeInner {
    exe: xla::PjRtLoadedExecutable,
    name: String,
    compile_time: Duration,
}

/// Wraps the process-wide PJRT CPU client plus cumulative execution stats
/// and a compiled-executable cache (keyed by artifact path — compiling an
/// artifact costs seconds; a multi-stage or repeated run must pay it
/// once; see EXPERIMENTS.md §Perf iteration 1).
pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<ExeInner>>>,
    /// Cumulative wall time spent inside PJRT `execute` (profiling).
    pub exec_time: std::cell::Cell<Duration>,
    pub exec_count: std::cell::Cell<u64>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Engine {
            client,
            cache: RefCell::new(HashMap::new()),
            exec_time: std::cell::Cell::new(Duration::ZERO),
            exec_count: std::cell::Cell::new(0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client, reusing
    /// the cached compilation when the same path was loaded before.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable<'_>> {
        let path = path.as_ref();
        let key = path.to_string_lossy().into_owned();
        if let Some(inner) = self.cache.borrow().get(&key) {
            return Ok(Executable { engine: self, inner: inner.clone() });
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e}"))?;
        let inner = Rc::new(ExeInner {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            compile_time: t0.elapsed(),
        });
        self.cache.borrow_mut().insert(key, inner.clone());
        Ok(Executable { engine: self, inner })
    }

    /// Number of distinct compiled artifacts currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// A (shared) compiled artifact. Outputs are always a single tuple
/// (lowered with `return_tuple=True`); `run` unwraps it to a flat literal
/// list.
pub struct Executable<'a> {
    engine: &'a Engine,
    inner: Rc<ExeInner>,
}

impl<'a> Executable<'a> {
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Compile time of the cached executable (zero-cost on cache hits).
    pub fn compile_time(&self) -> Duration {
        self.inner.compile_time
    }

    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let out = self
            .inner
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e}", self.inner.name))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| {
            anyhow!("fetching result of {}: {e}", self.inner.name)
        })?;
        let e = self.engine;
        e.exec_time.set(e.exec_time.get() + t0.elapsed());
        e.exec_count.set(e.exec_count.get() + 1);
        lit.to_tuple()
            .map_err(|e| anyhow!("untupling {}: {e}", self.inner.name))
    }

    /// Execute with device-resident buffers (hot-path variant: state stays
    /// on device between steps; see EXPERIMENTS.md §Perf).
    pub fn run_b(
        &self,
        inputs: &[xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let t0 = Instant::now();
        let mut out = self
            .inner
            .exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("executing {}: {e}", self.inner.name))?;
        let e = self.engine;
        e.exec_time.set(e.exec_time.get() + t0.elapsed());
        e.exec_count.set(e.exec_count.get() + 1);
        Ok(out.remove(0).remove(0))
    }
}

// ---------------------------------------------------------------------
// Literal construction / extraction helpers
// ---------------------------------------------------------------------

/// 1-D f32 literal.
pub fn lit_f32(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// 2-D i32 literal of shape [rows, cols].
pub fn lit_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// 2-D f32 literal of shape [rows, cols].
pub fn lit_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Rank-0 f32 literal.
pub fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract a f32 vector.
pub fn vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e}"))
}

/// Extract a f32 scalar (rank-0 or single-element).
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0]);
        assert_eq!(vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(scalar_f32(&lit_scalar(4.5)).unwrap(), 4.5);
    }

    #[test]
    fn reshape_checks_size() {
        assert!(lit_i32_2d(&[1, 2, 3], 2, 2).is_err());
        let l = lit_i32_2d(&[1, 2, 3, 4], 2, 2).unwrap();
        assert_eq!(l.element_count(), 4);
    }
}
