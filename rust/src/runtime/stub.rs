//! Offline stand-in for the PJRT runtime (default build, feature `pjrt`
//! disabled). Mirrors `runtime::pjrt`'s public surface exactly so every
//! caller compiles unchanged; any attempt to actually construct the
//! engine or execute an artifact returns a runtime error pointing at the
//! `pjrt` feature.

use std::cell::Cell;
use std::marker::PhantomData;
use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, Error, Result};

fn no_pjrt(what: &str) -> Error {
    anyhow!(
        "{what} requires the PJRT runtime — rebuild with `--features pjrt` \
         (needs the xla crate; this build is the offline stub)"
    )
}

/// Host-side literal placeholder. Construction is allowed (so batch
/// plumbing code is exercised even offline); only execution/extraction
/// requires the real backend.
pub struct Literal(());

/// Stub of the process-wide PJRT engine. `cpu()` always fails; the
/// fields exist for API parity with the real engine's profiling counters.
pub struct Engine {
    /// Cumulative wall time spent inside PJRT `execute` (always zero).
    pub exec_time: Cell<Duration>,
    pub exec_count: Cell<u64>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Err(no_pjrt("runtime::Engine::cpu()"))
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn load(&self, _path: impl AsRef<Path>) -> Result<Executable<'_>> {
        Err(no_pjrt("loading an artifact"))
    }

    pub fn cached_executables(&self) -> usize {
        0
    }
}

/// Stub compiled artifact (never actually constructible, since `Engine`
/// itself cannot be built in the stub configuration).
pub struct Executable<'a> {
    _engine: PhantomData<&'a Engine>,
}

impl<'a> Executable<'a> {
    pub fn name(&self) -> &str {
        "stub"
    }

    pub fn compile_time(&self) -> Duration {
        Duration::ZERO
    }

    pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        Err(no_pjrt("executing an artifact"))
    }
}

// ---------------------------------------------------------------------
// Literal construction / extraction helpers (same signatures as pjrt)
// ---------------------------------------------------------------------

/// 1-D f32 literal.
pub fn lit_f32(_data: &[f32]) -> Literal {
    Literal(())
}

/// 2-D i32 literal of shape [rows, cols].
pub fn lit_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<Literal> {
    anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
    Ok(Literal(()))
}

/// 2-D f32 literal of shape [rows, cols].
pub fn lit_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<Literal> {
    anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
    Ok(Literal(()))
}

/// Rank-0 f32 literal.
pub fn lit_scalar(_x: f32) -> Literal {
    Literal(())
}

/// Extract a f32 vector.
pub fn vec_f32(_lit: &Literal) -> Result<Vec<f32>> {
    Err(no_pjrt("reading a literal"))
}

/// Extract a f32 scalar (rank-0 or single-element).
pub fn scalar_f32(_lit: &Literal) -> Result<f32> {
    Err(no_pjrt("reading a literal"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_cpu_points_at_pjrt_feature() {
        let err = Engine::cpu().err().expect("stub must refuse");
        let msg = err.to_string();
        assert!(msg.contains("--features pjrt"), "{msg}");
    }

    #[test]
    fn literal_shapes_still_checked() {
        assert!(lit_i32_2d(&[1, 2, 3], 2, 2).is_err());
        assert!(lit_i32_2d(&[1, 2, 3, 4], 2, 2).is_ok());
        assert!(lit_f32_2d(&[1.0; 6], 2, 3).is_ok());
    }
}
