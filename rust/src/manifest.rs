//! `artifacts/manifest.json` — the contract between the Python compile
//! path and this coordinator. Parsed once at startup; everything the Rust
//! side knows about models (parameter segment table, shapes, init) and
//! artifacts (file names, I/O signatures) comes from here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parameter initializer, mirrored from python `ParamSpec.init`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Init {
    Normal(f32),
    Zeros,
    Ones,
}

impl Init {
    fn parse(s: &str) -> Result<Init> {
        if let Some(std) = s.strip_prefix("normal:") {
            return Ok(Init::Normal(std.parse()?));
        }
        match s {
            "zeros" => Ok(Init::Zeros),
            "ones" => Ok(Init::Ones),
            _ => bail!("unknown init spec {s:?}"),
        }
    }
}

/// One layer/tensor segment of the flat parameter vector.
#[derive(Clone, Debug)]
pub struct ParamSeg {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
    pub offset: usize,
    pub size: usize,
    /// Weight decay applies (false for biases / layer-norm).
    pub decay: bool,
    /// Layerwise adaptation applies (trust ratio pinned to 1 when false).
    pub adapt: bool,
}

/// A BERT-family model description.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub max_seq: usize,
    pub total_params: usize,
    pub params: Vec<ParamSeg>,
}

impl ModelMeta {
    /// Approximate forward+backward FLOPs per token (the 6N rule plus the
    /// attention term) — feeds the pod performance model.
    pub fn train_flops_per_token(&self, seq: usize) -> f64 {
        let n = self.total_params as f64;
        // 6N for dense matmuls + 12*L*H*S for attention scores/context.
        6.0 * n + 12.0 * (self.layers * self.hidden * seq) as f64
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// (params, tokens, targets, mask) -> (loss, grads)
    Grad,
    /// (params, tokens, targets, mask) -> (loss, acc)
    Eval,
    /// (params, grads, m, v, lr, step) -> (params', m', v', ratios)
    Opt,
    /// fused train step: (params, m, v, batch..., lr, step)
    /// -> (params', m', v', loss, ratios)
    Step,
}

#[derive(Clone, Debug)]
pub struct TensorSig {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub kind: ArtifactKind,
    pub model: String,
    pub seq: Option<usize>,
    pub micro_batch: Option<usize>,
    pub optimizer: Option<String>,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelMeta>,
    pub artifacts: Vec<ArtifactMeta>,
}

fn sigs(j: &Json) -> Result<Vec<TensorSig>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("sig list not an array"))?
        .iter()
        .map(|s| {
            Ok(TensorSig {
                name: s.get("name").and_then(Json::as_str).unwrap_or("").into(),
                dtype: s.get("dtype").and_then(Json::as_str).unwrap_or("f32").into(),
                shape: s
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, mj) in j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let cfg = mj.get("config").ok_or_else(|| anyhow!("model config"))?;
            let gu = |k: &str| -> Result<usize> {
                cfg.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("config field {k}"))
            };
            let mut params = Vec::new();
            for p in mj
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("model params"))?
            {
                params.push(ParamSeg {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param name"))?
                        .into(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default(),
                    init: Init::parse(
                        p.get("init").and_then(Json::as_str).unwrap_or("zeros"),
                    )?,
                    offset: p.get("offset").and_then(Json::as_usize).unwrap_or(0),
                    size: p.get("size").and_then(Json::as_usize).unwrap_or(0),
                    decay: p.get("decay").and_then(Json::as_bool).unwrap_or(true),
                    adapt: p.get("adapt").and_then(Json::as_bool).unwrap_or(true),
                });
            }
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    vocab: gu("vocab")?,
                    hidden: gu("hidden")?,
                    layers: gu("layers")?,
                    heads: gu("heads")?,
                    ff: gu("ff")?,
                    max_seq: gu("max_seq")?,
                    total_params: mj
                        .get("total_params")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("total_params"))?,
                    params,
                },
            );
        }

        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let kind = match a.get("kind").and_then(Json::as_str) {
                Some("grad") => ArtifactKind::Grad,
                Some("eval") => ArtifactKind::Eval,
                Some("opt") => ArtifactKind::Opt,
                Some("step") => ArtifactKind::Step,
                k => bail!("unknown artifact kind {k:?}"),
            };
            artifacts.push(ArtifactMeta {
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact file"))?
                    .into(),
                kind,
                model: a
                    .get("model")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .into(),
                seq: a.get("seq").and_then(Json::as_usize),
                micro_batch: a.get("micro_batch").and_then(Json::as_usize),
                optimizer: a
                    .get("optimizer")
                    .and_then(Json::as_str)
                    .map(String::from),
                inputs: sigs(a.get("inputs").unwrap_or(&Json::Arr(vec![])))?,
                outputs: sigs(a.get("outputs").unwrap_or(&Json::Arr(vec![])))?,
            });
        }

        Ok(Manifest { dir, models, artifacts })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }

    fn find(
        &self,
        kind: ArtifactKind,
        model: &str,
        seq: Option<usize>,
        opt: Option<&str>,
    ) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| {
                a.kind == kind
                    && a.model == model
                    && (seq.is_none() || a.seq == seq)
                    && (opt.is_none() || a.optimizer.as_deref() == opt)
            })
            .ok_or_else(|| {
                anyhow!(
                    "no {kind:?} artifact for model={model} seq={seq:?} opt={opt:?}"
                )
            })
    }

    pub fn grad(&self, model: &str, seq: usize) -> Result<&ArtifactMeta> {
        self.find(ArtifactKind::Grad, model, Some(seq), None)
    }

    pub fn eval(&self, model: &str, seq: usize) -> Result<&ArtifactMeta> {
        self.find(ArtifactKind::Eval, model, Some(seq), None)
    }

    pub fn opt(&self, model: &str, optimizer: &str) -> Result<&ArtifactMeta> {
        self.find(ArtifactKind::Opt, model, None, Some(optimizer))
    }

    pub fn step(
        &self,
        model: &str,
        seq: usize,
        optimizer: &str,
    ) -> Result<&ArtifactMeta> {
        self.find(ArtifactKind::Step, model, Some(seq), Some(optimizer))
    }

    pub fn path(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_parse() {
        assert_eq!(Init::parse("normal:0.02").unwrap(), Init::Normal(0.02));
        assert_eq!(Init::parse("zeros").unwrap(), Init::Zeros);
        assert_eq!(Init::parse("ones").unwrap(), Init::Ones);
        assert!(Init::parse("uniform").is_err());
    }

    #[test]
    fn flops_model_monotone_in_params() {
        let mk = |n: usize| ModelMeta {
            name: "m".into(),
            vocab: 100,
            hidden: 8,
            layers: 2,
            heads: 2,
            ff: 16,
            max_seq: 128,
            total_params: n,
            params: vec![],
        };
        assert!(
            mk(2_000_000).train_flops_per_token(128)
                > mk(1_000_000).train_flops_per_token(128)
        );
    }
}
