//! Minimal offline-vendored subset of the `anyhow` error-handling API.
//!
//! The build environment for this repository is fully offline (no
//! crates.io registry), so the crate graph must be self-contained. This
//! shim provides the exact surface `lamb-train` uses — `Error`, `Result`,
//! the `anyhow!` / `bail!` / `ensure!` macros and the `Context` extension
//! trait — with the same semantics for message construction and context
//! chaining. Error *messages* are preserved; the structured source chain
//! and backtraces of the real crate are not (nothing here consumes them).

use std::fmt::{self, Debug, Display};

/// A string-backed error value, convertible from any `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with an outer context line, matching anyhow's
    /// "context: cause" rendering in `{:#}` / `Debug` output.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints errors via Debug; keep
        // that output human-readable.
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `Result` with `Error` as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    /// Sealed unification of "things that can become `crate::Error`":
    /// every std error plus `Error` itself. The concrete `Error` impl and
    /// the blanket std-error impl are coherent because `Error`
    /// (deliberately) does not implement `std::error::Error`.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::msg(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` (any error type, including `anyhow::Error`) and `Option`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a single displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn macros_and_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        let f = || -> Result<()> { bail!("no {}", "good") };
        assert_eq!(f().unwrap_err().to_string(), "no good");
        let g = |v: i32| -> Result<()> {
            ensure!(v > 0, "v must be positive, got {v}");
            Ok(())
        };
        assert!(g(1).is_ok());
        assert_eq!(
            g(-1).unwrap_err().to_string(),
            "v must be positive, got -1"
        );
    }

    #[test]
    fn context_on_std_result_option_and_error() {
        let e = io_err().context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");
        let e = None::<u32>.with_context(|| "missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        // context on an already-anyhow Result (the chained case)
        let inner: Result<()> = Err(anyhow!("inner"));
        let e = inner.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let f = || -> Result<i32> {
            let v: i32 = "12".parse()?;
            Ok(v)
        };
        assert_eq!(f().unwrap(), 12);
        let g = || -> Result<i32> {
            let v: i32 = "nope".parse()?;
            Ok(v)
        };
        assert!(g().is_err());
    }
}
